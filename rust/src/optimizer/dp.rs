//! Algorithm 1: throughput maximization by dynamic programming.
//!
//! State `D(i, j, k)`: minimum achievable per-layer latency when the
//! first `i` GPUs process total batch `j` with total microbatch-size sum
//! `k` (the aggregate-compute-memory proxy for constraint III).
//! Transition: GPU i takes `l` microbatches of size `m` at cost
//! `T_{i,l,m} = max(T_f, AG') + max(T_b, AG' + RS')` (Eqs. 2, 3), where
//! the collectives switch to the +15% uneven variants whenever the even
//! training-state share cannot fit next to the GPU's compute memory
//! (Algorithm 1's check).
//!
//! Performance engineering vs. the paper's O(N B^3 log B) reference:
//! * optional batch quantization `granularity` (configs restricted to
//!   multiples of q) bounds the table for B = 1024 runs;
//! * `k` is capped by both Σ m_max_i and the aggregate-memory budget;
//! * per-(i, m) costs are precomputed once per `l` loop;
//! * rolling DP layers keep memory at 2 B² floats + the u16 choice
//!   table for backtracking.

use super::{Assignment, GpuAssign, PlanError};
use crate::memory::{state_bytes, usable_capacity, ParamResidency};
use crate::perfmodel::collective::UNEVEN_OVERHEAD;
use crate::perfmodel::ClusterPerfProfile;

/// Tunables for the solver.
#[derive(Debug, Clone)]
pub struct DpOptimizer {
    /// Batch quantization in samples; 0 = auto (keep table ~256 wide).
    pub granularity: usize,
    /// Upper bound on microbatch size considered (0 = no bound beyond
    /// memory).
    pub max_microbatch: usize,
    /// Parameter-residency accounting for the memory constraints:
    /// fully sharded (default, the §2.3 model — per-GPU state shrinks
    /// with `r_i`) or leader-resident (a replicated 4 B/param weight
    /// copy charges every GPU — the pre-sharding trainer's footprint).
    ///
    /// DELIBERATELY not wired to the trainer's `shard_params` flag:
    /// planning stays on the paper's model in both execution modes so
    /// a sharded run and its leader-resident reference solve to the
    /// SAME assignment (that shared plan is what makes the invariant-11
    /// bitwise comparison well-posed). Leader-resident accounting is a
    /// comparison mode for sweeps, not an execution default.
    pub residency: ParamResidency,
}

impl Default for DpOptimizer {
    fn default() -> Self {
        Self {
            granularity: 0,
            max_microbatch: 0,
            residency: ParamResidency::FullySharded,
        }
    }
}

/// Solver diagnostics (Table 7 reporting).
#[derive(Debug, Clone, Default)]
pub struct DpStats {
    pub states_visited: u64,
    pub transitions: u64,
    pub granularity: usize,
    pub k_max: usize,
    pub solve_seconds: f64,
}

impl DpOptimizer {
    /// Solve for `batch` over `profile`; returns the assignment with
    /// state ratios filled by the greedy partitioner.
    pub fn solve(&self, profile: &ClusterPerfProfile, batch: usize)
        -> Result<(Assignment, DpStats), PlanError> {
        let t0 = std::time::Instant::now();
        let n = profile.num_gpus();
        if batch == 0 || n == 0 {
            return Err(PlanError::Infeasible("empty batch or cluster".into()));
        }
        let q = if self.granularity > 0 {
            self.granularity
        } else {
            // Auto: keep the table ~256 wide, but round DOWN to the
            // largest divisor of `batch` so quantization never makes a
            // feasible batch (e.g. 1000 -> naive q=3) report Infeasible.
            let auto = (batch / 256).max(1);
            (1..=auto).rev().find(|d| batch % d == 0).unwrap_or(1)
        };
        if batch % q != 0 {
            return Err(PlanError::Infeasible(format!(
                "batch {batch} not divisible by granularity {q}"
            )));
        }
        let bq = batch / q; // table width in quanta

        // Per-GPU max microbatch (in quanta) under the 80% memory cap,
        // leaving no room for SHARDED state (that may go elsewhere) but
        // always charging the residency's fixed bytes (the replicated
        // weight copy never goes elsewhere).
        let fixed = self.residency.fixed_bytes(profile.total_params);
        let mut m_max = vec![0usize; n];
        for (i, g) in profile.per_gpu.iter().enumerate() {
            let cap = usable_capacity(g.capacity);
            let mm = g.mem.max_microbatch(cap, fixed).unwrap_or(0);
            let mut mq = mm / q;
            if self.max_microbatch > 0 {
                mq = mq.min(self.max_microbatch / q.max(1));
            }
            m_max[i] = mq.min(bq);
        }
        if m_max.iter().all(|&m| m == 0) {
            return Err(PlanError::oom(0, f64::INFINITY, 0.0));
        }

        // k upper bound: sum of per-GPU max microbatches, batch, and the
        // aggregate memory budget (constraint III) expressed in quanta.
        // Under leader residency the replicated copies charge n x fixed
        // and only the sharded remainder is distributable.
        let total_state =
            n as f64 * fixed + self.residency.sharded_bytes(profile.total_params);
        let total_cap: f64 = profile
            .per_gpu
            .iter()
            .map(|g| usable_capacity(g.capacity))
            .sum();
        let intercepts: f64 =
            profile.per_gpu.iter().map(|g| g.mem.intercept).sum();
        let avg_slope: f64 = profile
            .per_gpu
            .iter()
            .map(|g| g.mem.slope)
            .sum::<f64>()
            / n as f64;
        let mem_budget = total_cap - total_state - intercepts;
        if mem_budget < 0.0 {
            return Err(PlanError::Infeasible(
                "aggregate memory below training-state size".into(),
            ));
        }
        let k_budget = if avg_slope > 0.0 {
            ((mem_budget / avg_slope) / q as f64).floor() as usize
        } else {
            bq
        };
        let k_max = bq
            .min(m_max.iter().sum::<usize>())
            .min(k_budget.max(1));
        if k_max == 0 {
            return Err(PlanError::Infeasible(
                "aggregate memory admits no compute".into(),
            ));
        }

        // Even per-GPU resident state share for the uneven-collective
        // switch: identical to `profile.even_state_share()` when fully
        // sharded; leader residency adds the replicated copy.
        let even_share = fixed
            + self.residency.sharded_bytes(profile.total_params) / n as f64;
        // Comm is charged by edge class for the LOCALITY-ORDERED ring
        // the runtime walks (transport::collectives::RingOrder): one
        // cross-host chunk per NIC per step, so the price is bitwise
        // the classic bottleneck time and `brute_force` (which charges
        // the classic model) stays an exact oracle for this DP.
        let ag = profile.unit_allgather_ordered();
        let rs = profile.unit_reduce_scatter_ordered();
        let ag_u = ag * (1.0 + UNEVEN_OVERHEAD);
        let rs_u = rs * (1.0 + UNEVEN_OVERHEAD);

        let width = bq + 1;
        let kw = k_max + 1;
        let idx = |j: usize, k: usize| j * kw + k;
        // f32 table: per-layer latencies are O(1 s) with >= 1e-4 s
        // resolution, comfortably inside f32; halving the table's
        // memory traffic is a measured ~25% solve speedup (§Perf).
        const INF: f32 = f32::INFINITY;

        let mut prev = vec![INF; width * kw];
        let mut cur = vec![INF; width * kw];
        prev[idx(0, 0)] = 0.0;
        // choice[i][j][k] = (m_quanta, l); (0,0) = skip GPU i.
        let mut choice = vec![(0u16, 0u16); n * width * kw];
        let mut stats = DpStats {
            granularity: q,
            k_max,
            ..Default::default()
        };

        // Per-prefix reachability bound on k: after GPU i, the total
        // microbatch sum cannot exceed the sum of the first i+1 m_max
        // values — looping k further only touches INF states.
        let mut k_prefix = 0usize;
        for i in 0..n {
            // Skip option: GPU i gets no compute — elementwise carry of
            // the previous layer (unreachable states stay INF).
            cur.copy_from_slice(&prev);
            for c in choice[(i * width) * kw..(i + 1) * width * kw]
                .iter_mut()
            {
                *c = (0, 0);
            }
            k_prefix = (k_prefix + m_max[i]).min(k_max);
            let g = &profile.per_gpu[i];
            let cap = usable_capacity(g.capacity);
            // Precompute per-m data for this GPU.
            let mut per_m: Vec<(f32, f32, f64)> = Vec::with_capacity(
                m_max[i] + 1,
            ); // (fwd_one, bwd_one, mem)
            per_m.push((0.0, 0.0, 0.0));
            for mq in 1..=m_max[i] {
                let m = mq * q;
                per_m.push((
                    g.fwd.predict(m) as f32,
                    g.bwd.predict(m) as f32,
                    g.mem.predict(m),
                ));
            }
            let (ag32, rs32, ag_u32, rs_u32) =
                (ag as f32, rs as f32, ag_u as f32, rs_u as f32);
            for j in 0..width {
                // Dominance pruning: within a row, a state (j, k') with
                // k' < k and latency <= D[j][k] dominates (lower k only
                // RELAXES the aggregate-memory constraint and every
                // transition target), so (j, k) needs no expansion.
                let mut row_min = INF;
                for k in 0..kw.min(j + 1).min(k_prefix + 1) {
                    let base = prev[idx(j, k)];
                    stats.states_visited += 1;
                    if !base.is_finite() {
                        continue;
                    }
                    if base >= row_min {
                        continue; // dominated by a smaller-k state
                    }
                    row_min = base;
                    for mq in 1..=m_max[i].min(k_prefix - k) {
                        let (f1, b1, mem) = per_m[mq];
                        if mem + fixed > cap {
                            break;
                        }
                        // Uneven collectives when the even state share
                        // cannot sit next to this compute memory.
                        let (ag_i, rs_i) = if mem + even_share > cap {
                            (ag_u32, rs_u32)
                        } else {
                            (ag32, rs32)
                        };
                        let kn = k + mq;
                        let mut l = 1usize;
                        while j + l * mq <= bq {
                            let jn = j + l * mq;
                            stats.transitions += 1;
                            let tf = f1 * l as f32;
                            let tb = b1 * l as f32;
                            let t = tf.max(ag_i) + tb.max(ag_i + rs_i);
                            let cand = base.max(t);
                            let slot = idx(jn, kn);
                            if cand < cur[slot] {
                                cur[slot] = cand;
                                choice[(i * width + jn) * kw + kn] =
                                    (mq as u16, l as u16);
                            }
                            l += 1;
                        }
                    }
                }
            }
            std::mem::swap(&mut prev, &mut cur);
        }

        // Answer: min over k of D[N][bq][k] under constraint III.
        let mut best: Option<(usize, f64)> = None;
        for k in 0..kw {
            let v = prev[idx(bq, k)] as f64;
            if !v.is_finite() {
                continue;
            }
            // Aggregate memory re-check with the true quantized sum.
            let agg_mem = total_state
                + intercepts
                + avg_slope * (k * q) as f64;
            if agg_mem > total_cap {
                continue;
            }
            if best.map(|(_, bv)| v < bv).unwrap_or(true) {
                best = Some((k, v));
            }
        }
        let (mut k, layer_latency) = best.ok_or_else(|| {
            PlanError::Infeasible(
                "no feasible (batch, microbatch) division".into(),
            )
        })?;

        // Backtrack.
        let mut per_gpu = vec![
            GpuAssign { microbatch: 0, num_micro: 0, state_ratio: 0.0 };
            n
        ];
        let mut j = bq;
        for i in (0..n).rev() {
            let (mq, l) = choice[(i * width + j) * kw + k];
            let (mq, l) = (mq as usize, l as usize);
            if mq > 0 {
                per_gpu[i].microbatch = mq * q;
                per_gpu[i].num_micro = l;
                j -= mq * l;
                k -= mq;
            }
        }
        if j != 0 {
            return Err(PlanError::Internal(format!(
                "backtrack left {j} quanta unassigned"
            )));
        }

        // State partition (greedy, §2.4) fills the ratios.
        super::greedy::partition_state_resident(
            profile,
            &mut per_gpu,
            self.residency,
        )?;

        let mut asg = Assignment {
            per_gpu,
            layer_latency,
            iter_latency: layer_latency * profile.layers as f64,
        };
        // Keep ratios exactly normalized.
        let rsum: f64 = asg.per_gpu.iter().map(|g| g.state_ratio).sum();
        if rsum > 0.0 {
            for g in asg.per_gpu.iter_mut() {
                g.state_ratio /= rsum;
            }
        }
        stats.solve_seconds = t0.elapsed().as_secs_f64();
        Ok((asg, stats))
    }
}

/// Exhaustive reference solver for tiny instances — the test oracle for
/// the DP (DESIGN.md invariant 5). Enumerates every (m_i, l_i) division.
pub fn brute_force(profile: &ClusterPerfProfile, batch: usize)
    -> Option<f64> {
    let even_share = profile.even_state_share();
    let ag = profile.unit_allgather();
    let rs = profile.unit_reduce_scatter();
    let ag_u = profile.unit_allgather_uneven();
    let rs_u = profile.unit_reduce_scatter_uneven();
    let total_state = state_bytes(profile.total_params);
    let total_cap: f64 = profile
        .per_gpu
        .iter()
        .map(|g| usable_capacity(g.capacity))
        .sum();

    fn rec(
        i: usize,
        remaining: usize,
        acc_mem: f64,
        acc_cost: f64,
        profile: &ClusterPerfProfile,
        consts: (f64, f64, f64, f64, f64, f64, f64),
        best: &mut Option<f64>,
    ) {
        let (even_share, ag, rs, ag_u, rs_u, total_state, total_cap) = consts;
        let n = profile.num_gpus();
        if i == n {
            if remaining == 0 && total_state + acc_mem <= total_cap {
                if best.map(|b| acc_cost < b).unwrap_or(true) {
                    *best = Some(acc_cost);
                }
            }
            return;
        }
        let g = &profile.per_gpu[i];
        let cap = usable_capacity(g.capacity);
        // Skip.
        rec(i + 1, remaining, acc_mem, acc_cost, profile, consts, best);
        for m in 1..=remaining {
            let mem = g.mem.predict(m);
            if mem > cap {
                break;
            }
            let (agx, rsx) = if mem + even_share > cap {
                (ag_u, rs_u)
            } else {
                (ag, rs)
            };
            for l in 1..=(remaining / m) {
                let tf = g.fwd.predict(m) * l as f64;
                let tb = g.bwd.predict(m) * l as f64;
                let t = tf.max(agx) + tb.max(agx + rsx);
                rec(
                    i + 1,
                    remaining - m * l,
                    acc_mem + mem,
                    acc_cost.max(t),
                    profile,
                    consts,
                    best,
                );
            }
        }
    }

    let mut best = None;
    rec(
        0,
        batch,
        0.0,
        0.0,
        profile,
        (even_share, ag, rs, ag_u, rs_u, total_state, total_cap),
        &mut best,
    );
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::find_model;
    use crate::perfmodel::{Profiler, SyntheticOracle};

    fn profile_for(cluster: &Cluster, model: &str) -> ClusterPerfProfile {
        let m = find_model(model).unwrap();
        let oracle = SyntheticOracle::new(cluster, &m, 42);
        Profiler::default().profile(cluster, &m, &oracle)
    }

    #[test]
    fn solves_cluster_a_bert() {
        let p = profile_for(&Cluster::cluster_a(), "BERT-Large");
        let (asg, stats) =
            DpOptimizer::default().solve(&p, 128).expect("solvable");
        assert_eq!(asg.global_batch(), 128);
        assert!(asg.layer_latency > 0.0);
        assert!(stats.transitions > 0);
        asg.validate(&p, 128).expect("valid plan");
    }

    #[test]
    fn faster_gpus_get_bigger_batches() {
        let p = profile_for(&Cluster::cluster_a(), "BERT-Large");
        let (asg, _) = DpOptimizer::default().solve(&p, 128).unwrap();
        // GPU 2 = A6000 (38.7 TF), GPU 6/7 = P100 (9.3 TF).
        let a6000 = asg.per_gpu[2].batch();
        let p100 = asg.per_gpu[6].batch().max(asg.per_gpu[7].batch());
        assert!(
            a6000 > p100,
            "A6000 batch {a6000} should exceed P100 {p100}"
        );
    }

    #[test]
    fn matches_brute_force_on_small_instances() {
        // 2-GPU toy cluster (shared with the plan-parity tests).
        let cluster = crate::testkit::tiny_cluster();
        let p = profile_for(&cluster, "BERT-Large");
        for batch in [4usize, 6, 9, 12] {
            let (asg, _) = DpOptimizer {
                granularity: 1,
                ..Default::default()
            }
            .solve(&p, batch)
            .unwrap();
            let bf = brute_force(&p, batch).unwrap();
            // The DP table is f32 (see §Perf); allow f32 rounding.
            let rel = (asg.layer_latency - bf).abs() / bf;
            assert!(
                rel < 1e-6,
                "batch {batch}: dp {} vs brute force {bf}",
                asg.layer_latency
            );
        }
    }

    #[test]
    fn respects_memory_constraints() {
        let p = profile_for(&Cluster::cluster_a(), "GPT 2.7B");
        let (asg, _) = DpOptimizer::default().solve(&p, 128).unwrap();
        asg.validate(&p, 128).expect("no OOM");
    }

    #[test]
    fn quantization_auto_kicks_in_for_large_batches() {
        let p = profile_for(&Cluster::cluster_b(), "ViT-e");
        let (asg, stats) =
            DpOptimizer::default().solve(&p, 512).expect("solvable");
        assert!(stats.granularity >= 2);
        assert_eq!(asg.global_batch(), 512);
        asg.validate(&p, 512).unwrap();
    }

    #[test]
    fn auto_granularity_handles_non_pow2_batches() {
        // Regression: batch 1000 -> naive auto q = 1000/256 = 3 does
        // not divide 1000, which used to return Infeasible. The auto
        // pick must round down to a divisor (here 2).
        let p = profile_for(&Cluster::cluster_a(), "BERT-Large");
        let (asg, stats) = DpOptimizer::default()
            .solve(&p, 1000)
            .expect("non-power-of-two batch must stay feasible");
        assert_eq!(asg.global_batch(), 1000);
        assert!(stats.granularity > 1, "auto quantization should engage");
        assert_eq!(1000 % stats.granularity, 0);
        asg.validate(&p, 1000).unwrap();
        // An explicit non-divisor granularity still errors loudly.
        let err = DpOptimizer { granularity: 3, ..Default::default() }
            .solve(&p, 1000)
            .unwrap_err();
        assert!(err.to_string().contains("not divisible"));
    }

    #[test]
    fn sharded_residency_admits_what_leader_residency_cannot() {
        // The tentpole's memory claim, planner-side: on the residency
        // window (see `testkit::apply_residency_window`) every GPU
        // fits its compute plus a fully-sharded state share, but not a
        // replicated weight copy.
        let cluster = crate::testkit::window8_cluster();
        let mut p = profile_for(&cluster, "BERT-Large");
        crate::testkit::apply_residency_window(&mut p);
        // Fully sharded: feasible (per-GPU state shrinks with r_i).
        let sharded = DpOptimizer::default()
            .solve(&p, 8)
            .expect("fully-sharded accounting must admit this config");
        sharded
            .0
            .validate_resident(&p, 8, ParamResidency::FullySharded)
            .expect("sharded accounting fits");
        // Leader-resident: the replicated copy alone exceeds every
        // GPU's headroom -> a clean OOM, not a solver artifact.
        let leader = DpOptimizer {
            residency: ParamResidency::LeaderResident,
            ..Default::default()
        };
        let err = leader.solve(&p, 8).unwrap_err();
        assert!(err.is_oom(), "expected OOM, got: {err}");
        // And the sharded plan itself fails leader-resident validation.
        let verr = sharded
            .0
            .validate_resident(&p, 8, ParamResidency::LeaderResident)
            .unwrap_err();
        assert!(verr.is_oom(), "expected OOM, got: {verr}");
    }

    #[test]
    fn infeasible_when_model_exceeds_cluster() {
        use crate::cluster::{Node, Cluster};
        use crate::cluster::catalog::find;
        let tiny = Cluster {
            name: "tiny".into(),
            nodes: vec![Node {
                name: "n0".into(),
                gpus: vec![find("P100").unwrap()],
                intra_bw_gbps: 64.0,
            }],
            inter_bw_gbps: 50.0,
        };
        // Llama 7B state alone (~107 GB) >> one P100 (12 GB).
        let p = profile_for(&tiny, "Llama 7B");
        assert!(DpOptimizer::default().solve(&p, 8).is_err());
    }

    #[test]
    fn latency_decreases_with_cluster_size() {
        let pa = profile_for(&Cluster::cluster_b_subset(&["A10G"]), "ViT-e");
        let pall = profile_for(&Cluster::cluster_b(), "ViT-e");
        let (a, _) = DpOptimizer::default().solve(&pa, 256).unwrap();
        let (b, _) = DpOptimizer::default().solve(&pall, 256).unwrap();
        assert!(
            b.iter_latency < a.iter_latency,
            "more GPUs should be faster: {} vs {}",
            b.iter_latency,
            a.iter_latency
        );
    }
}
