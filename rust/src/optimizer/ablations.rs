//! Ablated planners for Fig. 7: compute balancing only (Cephalo-CB),
//! memory balancing only (Cephalo-MB), and the even-everything FSDP
//! baseline plan.

use super::{Assignment, GpuAssign, PlanError};
use crate::memory::{state_bytes, usable_capacity};
use crate::perfmodel::ClusterPerfProfile;

/// Predict the Eqs. 2/3 layer latency for a fixed per-GPU (m, l) set.
pub fn predict_layer_latency(
    profile: &ClusterPerfProfile,
    per_gpu: &[GpuAssign],
    uneven_state: bool,
) -> f64 {
    let (ag, rs) = if uneven_state {
        (profile.unit_allgather_uneven(), profile.unit_reduce_scatter_uneven())
    } else {
        (profile.unit_allgather(), profile.unit_reduce_scatter())
    };
    let tf = per_gpu
        .iter()
        .zip(&profile.per_gpu)
        .filter(|(g, _)| g.microbatch > 0)
        .map(|(g, m)| m.fwd.total(g.microbatch, g.num_micro))
        .fold(0.0, f64::max);
    let tb = per_gpu
        .iter()
        .zip(&profile.per_gpu)
        .filter(|(g, _)| g.microbatch > 0)
        .map(|(g, m)| m.bwd.total(g.microbatch, g.num_micro))
        .fold(0.0, f64::max);
    tf.max(ag) + tb.max(ag + rs)
}

fn finish(
    profile: &ClusterPerfProfile,
    per_gpu: Vec<GpuAssign>,
    batch: usize,
    uneven: bool,
) -> Result<Assignment, PlanError> {
    let layer = predict_layer_latency(profile, &per_gpu, uneven);
    let asg = Assignment {
        per_gpu,
        layer_latency: layer,
        iter_latency: layer * profile.layers as f64,
    };
    asg.validate(profile, batch)?;
    Ok(asg)
}

/// Cephalo-CB (§4.4): batch sizes proportional to compute speed, NO
/// gradient accumulation (m_i = b_i, l = 1), EVEN training state.
/// OOMs once per-GPU compute memory or the even state share no longer
/// fit — exactly the Fig.-7 failure mode beyond batch ~100.
pub fn compute_balanced_only(
    profile: &ClusterPerfProfile,
    batch: usize,
) -> Result<Assignment, PlanError> {
    let n = profile.num_gpus();
    // Speed proxy: saturated per-sample latency (inverse throughput).
    let speeds: Vec<f64> = profile
        .per_gpu
        .iter()
        .map(|g| {
            let m = 8;
            m as f64 / (g.fwd.predict(m) + g.bwd.predict(m))
        })
        .collect();
    let batches = proportional_split(batch, &speeds);
    let even_ratio = 1.0 / n as f64;
    let total_state = state_bytes(profile.total_params);
    let mut per_gpu = Vec::with_capacity(n);
    for (i, b) in batches.iter().enumerate() {
        let g = &profile.per_gpu[i];
        let cap = usable_capacity(g.capacity);
        let need = if *b > 0 { g.mem.predict(*b) } else { 0.0 }
            + even_ratio * total_state;
        if need > cap {
            return Err(PlanError::oom_in(
                i,
                need,
                cap,
                format!("cb: b_i={b}, even state"),
            ));
        }
        per_gpu.push(GpuAssign {
            microbatch: *b,
            num_micro: usize::from(*b > 0),
            state_ratio: even_ratio,
        });
    }
    finish(profile, per_gpu, batch, false)
}

/// Cephalo-MB (§4.4): memory balancing only — EVEN batch split,
/// microbatch fixed at 1 (maximal accumulation), UNEVEN state via the
/// greedy partitioner. Never OOMs but underutilizes compute.
pub fn memory_balanced_only(
    profile: &ClusterPerfProfile,
    batch: usize,
) -> Result<Assignment, PlanError> {
    let n = profile.num_gpus();
    if batch % n != 0 {
        return Err(PlanError::Infeasible(format!(
            "batch {batch} not divisible by {n} GPUs"
        )));
    }
    let b = batch / n;
    let mut per_gpu: Vec<GpuAssign> = (0..n)
        .map(|_| GpuAssign {
            microbatch: 1,
            num_micro: b,
            state_ratio: 0.0,
        })
        .collect();
    super::greedy::partition_state(profile, &mut per_gpu)?;
    finish(profile, per_gpu, batch, true)
}

/// Baseline FSDP plan: even batch, no accumulation, even state.
pub fn fsdp_even(
    profile: &ClusterPerfProfile,
    batch: usize,
) -> Result<Assignment, PlanError> {
    let n = profile.num_gpus();
    if batch % n != 0 {
        return Err(PlanError::Infeasible(format!(
            "batch {batch} not divisible by {n} GPUs"
        )));
    }
    let b = batch / n;
    let even_ratio = 1.0 / n as f64;
    let total_state = state_bytes(profile.total_params);
    for (i, g) in profile.per_gpu.iter().enumerate() {
        let cap = usable_capacity(g.capacity);
        let need = g.mem.predict(b) + even_ratio * total_state;
        if need > cap {
            return Err(PlanError::oom_in(
                i,
                need,
                cap,
                format!("even dp: b_i={b}, even state"),
            ));
        }
    }
    let per_gpu: Vec<GpuAssign> = (0..n)
        .map(|_| GpuAssign {
            microbatch: b,
            num_micro: 1,
            state_ratio: even_ratio,
        })
        .collect();
    finish(profile, per_gpu, batch, false)
}

/// Split `total` proportionally to `weights` with largest-remainder
/// rounding (Σ result == total).
pub fn proportional_split(total: usize, weights: &[f64]) -> Vec<usize> {
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0);
    let ideal: Vec<f64> =
        weights.iter().map(|w| w / wsum * total as f64).collect();
    let mut out: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let mut left = total - out.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        (ideal[b] - ideal[b].floor())
            .partial_cmp(&(ideal[a] - ideal[a].floor()))
            .unwrap()
    });
    for &i in &order {
        if left == 0 {
            break;
        }
        out[i] += 1;
        left -= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::find_model;
    use crate::optimizer::DpOptimizer;
    use crate::perfmodel::{Profiler, SyntheticOracle};

    fn profile(model: &str) -> ClusterPerfProfile {
        let cluster = Cluster::cluster_a();
        let m = find_model(model).unwrap();
        let oracle = SyntheticOracle::new(&cluster, &m, 42);
        Profiler::default().profile(&cluster, &m, &oracle)
    }

    #[test]
    fn proportional_split_sums() {
        let s = proportional_split(128, &[30.3, 30.3, 38.7, 11.8, 11.8,
                                          11.8, 9.3, 9.3]);
        assert_eq!(s.iter().sum::<usize>(), 128);
        assert!(s[2] > s[6]); // A6000 > P100
    }

    #[test]
    fn cb_ooms_at_large_batch_mb_does_not() {
        // Fig. 7: CB hits OOM beyond ~batch 100 on the big models; MB
        // keeps going.
        let p = profile("GPT 2.7B");
        assert!(compute_balanced_only(&p, 256).is_err());
        let mb = memory_balanced_only(&p, 256).expect("MB should fit");
        assert_eq!(mb.global_batch(), 256);
    }

    #[test]
    fn mb_is_slower_than_full_cephalo() {
        // Fig. 7: microbatch=1 underutilizes compute.
        let p = profile("ViT-e");
        let mb = memory_balanced_only(&p, 128).unwrap();
        let (full, _) = DpOptimizer::default().solve(&p, 128).unwrap();
        assert!(
            full.iter_latency < mb.iter_latency,
            "cephalo {} should beat MB {}",
            full.iter_latency,
            mb.iter_latency
        );
    }

    #[test]
    fn cephalo_beats_cb_when_cb_feasible() {
        let p = profile("BERT-Large");
        let cb = compute_balanced_only(&p, 64).expect("small batch fits");
        let (full, _) = DpOptimizer::default().solve(&p, 64).unwrap();
        assert!(full.iter_latency <= cb.iter_latency * 1.001);
    }

    #[test]
    fn fsdp_even_ooms_on_big_models() {
        // Table 8: baseline FSDP OOMs on GPT 2.7B at batch 128 (P100s'
        // 12 GB can't hold the even share + compute).
        let p = profile("GPT 2.7B");
        assert!(fsdp_even(&p, 128).is_err());
        // But works for BERT-Large at small batch.
        let p2 = profile("BERT-Large");
        assert!(fsdp_even(&p2, 64).is_ok());
    }
}
