//! The Cephalo optimizer (§2.4, Algorithm 1): dynamic programming over
//! (GPU prefix, batch allocated, aggregate microbatch size) to divide
//! compute, then greedy training-state partitioning to divide memory.

pub mod ablations;
pub mod dp;
pub mod greedy;

pub use dp::{DpOptimizer, DpStats};
pub use greedy::partition_state;

use crate::perfmodel::ClusterPerfProfile;

/// Per-GPU slice of the training configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuAssign {
    /// Microbatch size m_i (0 means the GPU receives no compute).
    pub microbatch: usize,
    /// Number of microbatches l_i.
    pub num_micro: usize,
    /// Training-state ratio r_i (sums to 1 across GPUs).
    pub state_ratio: f64,
}

impl GpuAssign {
    /// Local batch size b_i = m_i * l_i.
    pub fn batch(&self) -> usize {
        self.microbatch * self.num_micro
    }
}

/// A full training configuration for the cluster.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub per_gpu: Vec<GpuAssign>,
    /// Predicted single-layer latency T_f + T_b (Eqs. 2, 3).
    pub layer_latency: f64,
    /// Predicted full-iteration latency (layer latency x layers).
    pub iter_latency: f64,
}

impl Assignment {
    pub fn global_batch(&self) -> usize {
        self.per_gpu.iter().map(GpuAssign::batch).sum()
    }

    /// Predicted throughput in samples/second.
    pub fn throughput(&self) -> f64 {
        self.global_batch() as f64 / self.iter_latency
    }

    /// Sanity checks against a profile; used by tests and the trainer.
    pub fn validate(&self, profile: &ClusterPerfProfile, batch: usize)
        -> Result<(), PlanError> {
        if self.per_gpu.len() != profile.num_gpus() {
            return Err(PlanError::Internal("gpu count mismatch".into()));
        }
        if self.global_batch() != batch {
            return Err(PlanError::Internal(format!(
                "batch {} != requested {batch}",
                self.global_batch()
            )));
        }
        let rsum: f64 = self.per_gpu.iter().map(|g| g.state_ratio).sum();
        if (rsum - 1.0).abs() > 1e-6 {
            return Err(PlanError::Internal(format!(
                "state ratios sum to {rsum}"
            )));
        }
        // Per-GPU memory: compute + assigned state within the 80% cap.
        let total_state =
            crate::memory::state_bytes(profile.total_params);
        for (i, (g, m)) in
            self.per_gpu.iter().zip(&profile.per_gpu).enumerate()
        {
            let compute = if g.microbatch > 0 {
                m.mem.predict(g.microbatch)
            } else {
                0.0
            };
            let used = compute + g.state_ratio * total_state;
            let cap = crate::memory::usable_capacity(m.capacity);
            if used > cap * (1.0 + 1e-9) {
                return Err(PlanError::OutOfMemory {
                    gpu: i,
                    needed: used,
                    capacity: cap,
                });
            }
        }
        Ok(())
    }
}

/// Planning failures.
#[derive(Debug, Clone)]
pub enum PlanError {
    /// No configuration satisfies the memory constraints — the paper's
    /// "OOM" table entries.
    OutOfMemory { gpu: usize, needed: f64, capacity: f64 },
    /// The batch cannot be divided under the constraints.
    Infeasible(String),
    Internal(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::OutOfMemory { gpu, needed, capacity } => write!(
                f,
                "OOM on gpu {gpu}: needs {:.2} GB > usable {:.2} GB",
                needed / 1e9,
                capacity / 1e9
            ),
            PlanError::Infeasible(s) => write!(f, "infeasible: {s}"),
            PlanError::Internal(s) => write!(f, "internal: {s}"),
        }
    }
}

impl std::error::Error for PlanError {}
