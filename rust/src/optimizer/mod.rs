//! The Cephalo optimizer (§2.4, Algorithm 1): dynamic programming over
//! (GPU prefix, batch allocated, aggregate microbatch size) to divide
//! compute, then greedy training-state partitioning to divide memory.

pub mod ablations;
pub mod dp;
pub mod greedy;

pub use dp::{DpOptimizer, DpStats};
pub use greedy::{partition_state, partition_state_resident};

use crate::perfmodel::ClusterPerfProfile;

/// Per-GPU slice of the training configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuAssign {
    /// Microbatch size m_i (0 means the GPU receives no compute).
    pub microbatch: usize,
    /// Number of microbatches l_i.
    pub num_micro: usize,
    /// Training-state ratio r_i (sums to 1 across GPUs).
    pub state_ratio: f64,
}

impl GpuAssign {
    /// Local batch size b_i = m_i * l_i.
    pub fn batch(&self) -> usize {
        self.microbatch * self.num_micro
    }
}

/// A full training configuration for the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub per_gpu: Vec<GpuAssign>,
    /// Predicted single-layer latency T_f + T_b (Eqs. 2, 3).
    pub layer_latency: f64,
    /// Predicted full-iteration latency (layer latency x layers).
    pub iter_latency: f64,
}

impl Assignment {
    pub fn global_batch(&self) -> usize {
        self.per_gpu.iter().map(GpuAssign::batch).sum()
    }

    /// Predicted throughput in samples/second.
    pub fn throughput(&self) -> f64 {
        self.global_batch() as f64 / self.iter_latency
    }

    /// Sanity checks against a profile; used by tests and the trainer.
    /// Fully-sharded parameter accounting (the §2.3 model).
    pub fn validate(&self, profile: &ClusterPerfProfile, batch: usize)
        -> Result<(), PlanError> {
        self.validate_resident(
            profile,
            batch,
            crate::memory::ParamResidency::FullySharded,
        )
    }

    /// [`Assignment::validate`] under an explicit parameter residency:
    /// leader-resident accounting charges every GPU the replicated
    /// 4 B/param weight copy on top of its `r_i` share of the rest.
    pub fn validate_resident(
        &self,
        profile: &ClusterPerfProfile,
        batch: usize,
        residency: crate::memory::ParamResidency,
    ) -> Result<(), PlanError> {
        if self.per_gpu.len() != profile.num_gpus() {
            return Err(PlanError::Internal("gpu count mismatch".into()));
        }
        if self.global_batch() != batch {
            return Err(PlanError::Internal(format!(
                "batch {} != requested {batch}",
                self.global_batch()
            )));
        }
        let rsum: f64 = self.per_gpu.iter().map(|g| g.state_ratio).sum();
        if (rsum - 1.0).abs() > 1e-6 {
            return Err(PlanError::Internal(format!(
                "state ratios sum to {rsum}"
            )));
        }
        // Per-GPU memory: compute + assigned state within the 80% cap.
        for (i, (g, m)) in
            self.per_gpu.iter().zip(&profile.per_gpu).enumerate()
        {
            let compute = if g.microbatch > 0 {
                m.mem.predict(g.microbatch)
            } else {
                0.0
            };
            let used = compute
                + residency.per_gpu_state_bytes(
                    profile.total_params,
                    g.state_ratio,
                );
            let cap = crate::memory::usable_capacity(m.capacity);
            if used > cap * (1.0 + 1e-9) {
                return Err(PlanError::oom(i, used, cap));
            }
        }
        Ok(())
    }
}

/// Planning failures.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    /// No configuration satisfies the memory constraints — the paper's
    /// "OOM" table entries. `config` describes WHICH candidate
    /// configuration overflowed (microbatch / tp / dp ...), when the
    /// planner knows it.
    OutOfMemory {
        gpu: usize,
        needed: f64,
        capacity: f64,
        config: Option<String>,
    },
    /// The batch cannot be divided under the constraints.
    Infeasible(String),
    Internal(String),
    /// An error attributed to a named planner (`plan::Planner` impls
    /// tag their failures so sweep/CLI output names the system).
    Tagged { planner: String, inner: Box<PlanError> },
}

impl PlanError {
    /// OOM without a known candidate configuration.
    pub fn oom(gpu: usize, needed: f64, capacity: f64) -> PlanError {
        PlanError::OutOfMemory { gpu, needed, capacity, config: None }
    }

    /// OOM of a specific candidate configuration (Table 4/5 entries).
    pub fn oom_in(
        gpu: usize,
        needed: f64,
        capacity: f64,
        config: impl Into<String>,
    ) -> PlanError {
        PlanError::OutOfMemory {
            gpu,
            needed,
            capacity,
            config: Some(config.into()),
        }
    }

    /// Attribute this error to `planner` (idempotent: re-tagging an
    /// already-tagged error keeps the innermost attribution).
    pub fn tagged(self, planner: &str) -> PlanError {
        match self {
            e @ PlanError::Tagged { .. } => e,
            inner => PlanError::Tagged {
                planner: planner.to_string(),
                inner: Box::new(inner),
            },
        }
    }

    /// True for OOM, looking through planner tags.
    pub fn is_oom(&self) -> bool {
        matches!(self.untagged(), PlanError::OutOfMemory { .. })
    }

    /// The planner this error is attributed to, if any.
    pub fn planner(&self) -> Option<&str> {
        match self {
            PlanError::Tagged { planner, .. } => Some(planner),
            _ => None,
        }
    }

    /// The error with any planner attribution stripped.
    pub fn untagged(&self) -> &PlanError {
        match self {
            PlanError::Tagged { inner, .. } => inner.untagged(),
            e => e,
        }
    }
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::OutOfMemory { gpu, needed, capacity, config } => {
                match config {
                    Some(c) => write!(
                        f,
                        "OOM on gpu {gpu} ({c}): needs {:.2} GB > \
                         usable {:.2} GB",
                        needed / 1e9,
                        capacity / 1e9
                    ),
                    None => write!(
                        f,
                        "OOM on gpu {gpu}: needs {:.2} GB > usable \
                         {:.2} GB",
                        needed / 1e9,
                        capacity / 1e9
                    ),
                }
            }
            PlanError::Infeasible(s) => write!(f, "infeasible: {s}"),
            PlanError::Internal(s) => write!(f, "internal: {s}"),
            PlanError::Tagged { planner, inner } => {
                write!(f, "[{planner}] {inner}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_planner_and_config() {
        let e = PlanError::oom_in(3, 20e9, 10e9, "micro=16 x 2")
            .tagged("Whale");
        let s = e.to_string();
        assert!(s.contains("[Whale]"), "{s}");
        assert!(s.contains("micro=16 x 2"), "{s}");
        assert!(s.contains("gpu 3"), "{s}");
        assert!(e.is_oom());
        assert_eq!(e.planner(), Some("Whale"));
    }

    #[test]
    fn tagging_is_idempotent() {
        let e = PlanError::Infeasible("x".into())
            .tagged("HAP")
            .tagged("sweep");
        assert_eq!(e.planner(), Some("HAP"));
        assert!(!e.is_oom());
        assert_eq!(*e.untagged(), PlanError::Infeasible("x".into()));
    }
}
