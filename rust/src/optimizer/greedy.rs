//! Greedy training-state partitioning (§2.4 Training State Partition).
//!
//! After the DP fixes per-GPU compute memory M(m_i), the training state
//! is distributed to minimize the maximum memory *utilization ratio*
//! across GPUs: repeatedly hand the next state quantum to the GPU with
//! the lowest projected utilization. The paper's version is O(N²); ours
//! uses a binary heap for O(Q log N) over Q quanta.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::{GpuAssign, PlanError};
use crate::memory::{usable_capacity, ParamResidency};
use crate::perfmodel::ClusterPerfProfile;

/// Number of quanta the state is divided into for the greedy loop.
/// Finer quanta track the continuous optimum closer; 4096 keeps the
/// rounding error below 0.025% of the state.
const QUANTA: usize = 4096;

/// Min-heap entry ordered by projected utilization after receiving one
/// more quantum.
struct Entry {
    utilization: f64,
    gpu: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.utilization == other.utilization && self.gpu == other.gpu
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap on utilization; tie-break on gpu id for
        // determinism.
        other
            .utilization
            .partial_cmp(&self.utilization)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.gpu.cmp(&self.gpu))
    }
}

/// Fill `per_gpu[i].state_ratio` in place. Compute assignments
/// (microbatch sizes) must already be set. Fully-sharded accounting
/// (the paper's §2.3 model); see [`partition_state_resident`] for the
/// leader-resident comparison mode.
pub fn partition_state(
    profile: &ClusterPerfProfile,
    per_gpu: &mut [GpuAssign],
) -> Result<(), PlanError> {
    partition_state_resident(profile, per_gpu, ParamResidency::FullySharded)
}

/// [`partition_state`] under an explicit parameter residency: the
/// residency's fixed bytes (a replicated weight copy under
/// `LeaderResident`) charge every GPU up front, and only the sharded
/// remainder is distributed by the greedy loop.
pub fn partition_state_resident(
    profile: &ClusterPerfProfile,
    per_gpu: &mut [GpuAssign],
    residency: ParamResidency,
) -> Result<(), PlanError> {
    let n = per_gpu.len();
    assert_eq!(n, profile.num_gpus());
    let fixed = residency.fixed_bytes(profile.total_params);
    let total_state = residency.sharded_bytes(profile.total_params);
    let quantum = total_state / QUANTA as f64;

    // Fixed memory per GPU: compute plus any non-sharded state.
    let compute: Vec<f64> = per_gpu
        .iter()
        .zip(&profile.per_gpu)
        .map(|(g, m)| {
            fixed
                + if g.microbatch > 0 {
                    m.mem.predict(g.microbatch)
                } else {
                    // Idle GPUs still hold framework state.
                    m.mem.intercept
                }
        })
        .collect();
    let caps: Vec<f64> = profile
        .per_gpu
        .iter()
        .map(|m| usable_capacity(m.capacity))
        .collect();

    // Sanity: compute alone must fit.
    for i in 0..n {
        if compute[i] > caps[i] {
            return Err(PlanError::oom(i, compute[i], caps[i]));
        }
    }

    let mut assigned = vec![0f64; n];
    let mut heap = BinaryHeap::with_capacity(n);
    for i in 0..n {
        if compute[i] + quantum <= caps[i] {
            heap.push(Entry {
                utilization: (compute[i] + quantum) / caps[i],
                gpu: i,
            });
        }
    }
    for _ in 0..QUANTA {
        let Some(Entry { gpu, .. }) = heap.pop() else {
            return Err(PlanError::Infeasible(
                "training state does not fit in aggregate memory".into(),
            ));
        };
        assigned[gpu] += quantum;
        let next = compute[gpu] + assigned[gpu] + quantum;
        if next <= caps[gpu] {
            heap.push(Entry { utilization: next / caps[gpu], gpu });
        }
    }
    for (g, a) in per_gpu.iter_mut().zip(&assigned) {
        g.state_ratio = a / total_state;
    }
    Ok(())
}

/// Max utilization of a hypothetical ratio vector — the quantity the
/// greedy loop minimizes; exposed for the property tests.
pub fn max_utilization(
    profile: &ClusterPerfProfile,
    per_gpu: &[GpuAssign],
    ratios: &[f64],
) -> f64 {
    let total_state = crate::memory::state_bytes(profile.total_params);
    per_gpu
        .iter()
        .zip(&profile.per_gpu)
        .zip(ratios)
        .map(|((g, m), r)| {
            let compute = if g.microbatch > 0 {
                m.mem.predict(g.microbatch)
            } else {
                m.mem.intercept
            };
            (compute + r * total_state) / usable_capacity(m.capacity)
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::find_model;
    use crate::perfmodel::{Profiler, SyntheticOracle};
    use crate::testkit::check;

    fn profile() -> ClusterPerfProfile {
        let cluster = Cluster::cluster_a();
        let m = find_model("BERT-Large").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &m, 42);
        Profiler::default().profile(&cluster, &m, &oracle)
    }

    fn assigns(ms: &[usize]) -> Vec<GpuAssign> {
        ms.iter()
            .map(|&m| GpuAssign {
                microbatch: m,
                num_micro: if m > 0 { 1 } else { 0 },
                state_ratio: 0.0,
            })
            .collect()
    }

    #[test]
    fn ratios_sum_to_one() {
        let p = profile();
        let mut a = assigns(&[4; 8]);
        partition_state(&p, &mut a).unwrap();
        let sum: f64 = a.iter().map(|g| g.state_ratio).sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        assert!(a.iter().all(|g| g.state_ratio >= 0.0));
    }

    #[test]
    fn bigger_memory_gets_more_state() {
        let p = profile();
        let mut a = assigns(&[2; 8]);
        partition_state(&p, &mut a).unwrap();
        // GPU 2 is the 48 GB A6000; GPUs 6,7 are 12 GB P100s.
        assert!(a[2].state_ratio > a[6].state_ratio * 1.5);
        assert!(a[2].state_ratio > a[7].state_ratio * 1.5);
    }

    #[test]
    fn heavy_compute_gpu_gets_less_state() {
        let p = profile();
        // Same hardware (two P40s: indices 4 and 5), very different
        // compute loads.
        let mut a = assigns(&[1, 1, 1, 1, 32, 1, 1, 1]);
        partition_state(&p, &mut a).unwrap();
        assert!(
            a[5].state_ratio > a[4].state_ratio,
            "lightly-loaded P40 should take more state: {} vs {}",
            a[5].state_ratio,
            a[4].state_ratio
        );
    }

    #[test]
    fn prop_greedy_beats_sampled_alternatives() {
        // DESIGN.md invariant 6: no sampled alternative achieves lower
        // max utilization (up to one quantum of slack).
        let p = profile();
        let mut a = assigns(&[4, 4, 8, 2, 2, 2, 1, 1]);
        partition_state(&p, &mut a).unwrap();
        let greedy_ratios: Vec<f64> =
            a.iter().map(|g| g.state_ratio).collect();
        let greedy_util = max_utilization(&p, &a, &greedy_ratios);
        check("greedy-state-optimal", 60, |g| {
            let alt = g.ratios(8);
            let alt_util = max_utilization(&p, &a, &alt);
            assert!(
                alt_util >= greedy_util - 0.01,
                "alternative {alt_util} beats greedy {greedy_util}"
            );
        });
    }

    #[test]
    fn leader_residency_charges_every_gpu_for_the_weight_copy() {
        let p = profile();
        let ld = ParamResidency::LeaderResident;
        let fixed = ld.fixed_bytes(p.total_params);
        assert!(fixed > 0.0);
        let mut a = assigns(&[2; 8]);
        partition_state_resident(&p, &mut a, ld).unwrap();
        let sum: f64 = a.iter().map(|g| g.state_ratio).sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        // Every GPU fits with the replicated copy charged up front.
        let rest = ld.sharded_bytes(p.total_params);
        for (g, m) in a.iter().zip(&p.per_gpu) {
            let used = m.mem.predict(2) + fixed + g.state_ratio * rest;
            assert!(used <= usable_capacity(m.capacity) * (1.0 + 1e-9));
        }
        // Charging more total memory cannot lower the achievable max
        // utilization: leader-resident is never better than sharded.
        let mut sh = assigns(&[2; 8]);
        partition_state(&p, &mut sh).unwrap();
        let util = |per: &[GpuAssign], res: ParamResidency| {
            per.iter()
                .zip(&p.per_gpu)
                .map(|(g, m)| {
                    (m.mem.predict(2)
                        + res.per_gpu_state_bytes(
                            p.total_params,
                            g.state_ratio,
                        ))
                        / usable_capacity(m.capacity)
                })
                .fold(0.0, f64::max)
        };
        // (0.01 tolerance: both greedy results sit within one quantum
        // of their optima, same slack as invariant 6.)
        assert!(
            util(&a, ld) + 0.01
                >= util(&sh, ParamResidency::FullySharded),
            "replicated weights should never reduce peak utilization"
        );
    }

    #[test]
    fn infeasible_when_state_exceeds_memory() {
        // One node of cluster A (120 GB physical, 96 GB usable) cannot
        // hold Llama 7B's ~108 GB of fp32 Adam state.
        let full = Cluster::cluster_a();
        let cluster = Cluster {
            name: "A-node0".into(),
            nodes: vec![full.nodes[0].clone()],
            inter_bw_gbps: full.inter_bw_gbps,
        };
        let m = find_model("Llama 7B").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &m, 1);
        let p = Profiler::default().profile(&cluster, &m, &oracle);
        let mut a = assigns(&[8; 4]);
        assert!(partition_state(&p, &mut a).is_err());
    }
}
