//! Transformer workload descriptions (Table 2) and analytic accounting
//! of parameters, FLOPs and activation bytes — the quantities every
//! performance/memory model downstream consumes.

/// Task category from Table 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    ImageClassification,
    TextClassification,
    TextGeneration,
}

/// One transformer workload (Table 2 row).
#[derive(Debug, Clone)]
pub struct TransformerSpec {
    pub name: String,
    pub task: Task,
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    /// Sequence length used in training (512 for LM per §4.1; ViT uses
    /// its patch-token count).
    pub seq_len: usize,
    /// FFN hidden width (model-specific: ViTs and GPTs use ~4d, Llamas
    /// use the SwiGLU width).
    pub d_ff: usize,
    /// FFN weight matrices: 2 for GELU MLPs, 3 for SwiGLU (Llama).
    pub ffn_matrices: usize,
    /// Approximate vocabulary (LM head) or class count; contributes to
    /// embedding parameters.
    pub vocab: usize,
}

impl TransformerSpec {
    /// Parameters in one transformer layer:
    /// 4 d^2 (qkv+o) + ffn_matrices * d * d_ff + biases/LN.
    pub fn params_per_layer(&self) -> usize {
        let d = self.d_model;
        let dff = self.d_ff;
        4 * d * d + self.ffn_matrices * d * dff + dff + d + 4 * d
    }

    /// Total parameters (layers + embeddings + LM head).
    pub fn total_params(&self) -> usize {
        self.layers * self.params_per_layer()
            + self.vocab * self.d_model * 2
            + 2 * self.d_model
    }

    /// Per-FSDP-unit parameter counts: the layer stack split into
    /// `units` contiguous groups, as even as layer granularity allows
    /// (`units` is clamped to `[1, layers]`); embeddings + LM head
    /// ride with the first group. The transient gather peak of a
    /// unit-sharded step scales with the LARGEST entry — not with
    /// total parameters — which is what buys the capacity window that
    /// whole-model gather cannot fit.
    pub fn unit_param_counts(&self, units: usize) -> Vec<usize> {
        let units = units.clamp(1, self.layers.max(1));
        let per_layer = self.params_per_layer();
        let embed = self.vocab * self.d_model * 2 + 2 * self.d_model;
        let mut counts = vec![0usize; units];
        for l in 0..self.layers {
            counts[l * units / self.layers] += per_layer;
        }
        counts[0] += embed;
        counts
    }

    /// `max(unit_param_counts(units))`: the per-unit transient-peak
    /// driver in the planner's memory model.
    pub fn largest_unit_params(&self, units: usize) -> usize {
        self.unit_param_counts(units)
            .into_iter()
            .max()
            .unwrap_or(self.total_params())
    }

    /// Forward FLOPs for one layer on a batch of `m` sequences:
    /// QKV+O projections 8 s d^2, attention 4 s^2 d, FFN
    /// 2 * ffn_matrices * s * d * d_ff.
    pub fn layer_fwd_flops(&self, m: usize) -> f64 {
        let s = self.seq_len as f64;
        let d = self.d_model as f64;
        let dff = self.d_ff as f64;
        let per_seq = 8.0 * s * d * d
            + 4.0 * s * s * d
            + 2.0 * self.ffn_matrices as f64 * s * d * dff;
        per_seq * m as f64
    }

    /// Backward is ~2x forward (recompute for checkpointing adds ~1x
    /// more forward, folded in by the caller when enabled).
    pub fn layer_bwd_flops(&self, m: usize) -> f64 {
        2.0 * self.layer_fwd_flops(m)
    }

    /// Total model FLOPs for one fwd+bwd iteration on batch `b`, with
    /// activation recompute (fwd again during bwd) if `recompute`.
    pub fn iter_flops(&self, b: usize, recompute: bool) -> f64 {
        let fwd = self.layer_fwd_flops(b) * self.layers as f64;
        let bwd = self.layer_bwd_flops(b) * self.layers as f64;
        let re = if recompute { fwd } else { 0.0 };
        fwd + bwd + re
    }

    /// Boundary activation bytes per sample per layer (fp32): the
    /// checkpointed tensor is [s, d].
    pub fn boundary_activation_bytes(&self) -> f64 {
        (self.seq_len * self.d_model * 4) as f64
    }

    /// Peak intra-layer activation bytes per sample (fp32), when NOT
    /// recomputing: attention scores + ffn hidden dominate.
    pub fn intra_layer_activation_bytes(&self) -> f64 {
        let s = self.seq_len as f64;
        let d = self.d_model as f64;
        let dff = self.d_ff as f64;
        let h = self.heads as f64;
        4.0 * (h * s * s + s * dff + 6.0 * s * d)
    }

    /// Table 2 headline parameter count in billions (for display).
    pub fn params_b(&self) -> f64 {
        self.total_params() as f64 / 1e9
    }
}

/// The nine Table-2 models (+ GPT 1.3B used in Table 4).
pub fn table2_models() -> Vec<TransformerSpec> {
    use Task::*;
    let m = |name: &str, task, layers, d_model, heads, seq, d_ff, mats,
             vocab| TransformerSpec {
        name: name.into(),
        task,
        layers,
        d_model,
        heads,
        seq_len: seq,
        d_ff,
        ffn_matrices: mats,
        vocab,
    };
    vec![
        // ViTs process 224x224 images as 256 patch tokens (+1 cls);
        // widths/depths/mlp dims from Zhai et al. / Chen et al.
        m("ViT-G", ImageClassification, 48, 1664, 16, 257, 8192, 2, 1000),
        m("ViT-e", ImageClassification, 56, 1792, 16, 257, 15360, 2, 1000),
        m("BERT-Large", TextClassification, 24, 1024, 16, 512, 4096, 2, 30522),
        m("BERT-XLarge", TextClassification, 36, 1536, 24, 512, 6144, 2,
          30522),
        m("GPT 1.3B", TextGeneration, 24, 2048, 32, 512, 8192, 2, 50257),
        m("GPT 2.7B", TextGeneration, 32, 2560, 80, 512, 10240, 2, 50257),
        m("GPT 6.7B", TextGeneration, 32, 4096, 128, 512, 16384, 2, 50257),
        m("Tiny Llama", TextGeneration, 22, 2048, 32, 512, 5632, 3, 32000),
        m("Llama 3B", TextGeneration, 26, 3200, 32, 512, 8640, 3, 32000),
        m("Llama 7B", TextGeneration, 32, 4096, 32, 512, 11008, 3, 32000),
    ]
}

/// Look up a Table-2 model by (case-insensitive) name.
pub fn find_model(name: &str) -> Option<TransformerSpec> {
    let lower = name.to_ascii_lowercase();
    table2_models()
        .into_iter()
        .find(|m| m.name.to_ascii_lowercase() == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_table2_headlines() {
        // Table 2 parameter counts (billions); the analytic formula
        // should be within ~20%.
        let expect = [
            ("ViT-G", 1.8),
            ("ViT-e", 3.9),
            ("BERT-Large", 0.4),
            ("BERT-XLarge", 1.2),
            ("GPT 1.3B", 1.3),
            ("GPT 2.7B", 2.7),
            ("GPT 6.7B", 6.7),
            ("Tiny Llama", 1.1),
            ("Llama 3B", 3.5),
            ("Llama 7B", 6.7),
        ];
        for (name, billions) in expect {
            let m = find_model(name).unwrap();
            let got = m.params_b();
            let rel = (got - billions) / billions;
            assert!(
                rel.abs() < 0.20,
                "{name}: expected ~{billions}B, formula gives {got:.2}B"
            );
        }
    }

    #[test]
    fn unit_param_counts_tile_the_model_and_shrink_the_peak() {
        let m = find_model("GPT 1.3B").unwrap();
        // Any unit count tiles the model exactly.
        for units in [1, 2, 3, 8, m.layers, m.layers + 5] {
            let counts = m.unit_param_counts(units);
            assert_eq!(
                counts.iter().sum::<usize>(),
                m.total_params(),
                "units={units}"
            );
            assert!(counts.iter().all(|&c| c > 0), "units={units}");
        }
        // units=1 is the whole model; more units weakly shrink the
        // largest unit, and at layer granularity it approaches one
        // layer + the embedding block.
        assert_eq!(m.unit_param_counts(1), vec![m.total_params()]);
        let mut prev = m.largest_unit_params(1);
        for units in 2..=m.layers {
            let cur = m.largest_unit_params(units);
            assert!(cur <= prev, "largest unit grew at units={units}");
            prev = cur;
        }
        let embed = m.vocab * m.d_model * 2 + 2 * m.d_model;
        assert_eq!(
            m.largest_unit_params(m.layers),
            m.params_per_layer() + embed
        );
        // Clamped above layer granularity.
        assert_eq!(
            m.unit_param_counts(m.layers + 9).len(),
            m.layers
        );
    }

    #[test]
    fn layer_flops_scale_linearly_in_batch() {
        let m = find_model("BERT-Large").unwrap();
        let f1 = m.layer_fwd_flops(1);
        let f8 = m.layer_fwd_flops(8);
        assert!((f8 / f1 - 8.0).abs() < 1e-9);
        assert!(m.layer_bwd_flops(1) == 2.0 * f1);
    }

    #[test]
    fn iter_flops_recompute_adds_one_forward() {
        let m = find_model("BERT-Large").unwrap();
        let without = m.iter_flops(4, false);
        let with = m.iter_flops(4, true);
        let fwd = m.layer_fwd_flops(4) * m.layers as f64;
        assert!((with - without - fwd).abs() / fwd < 1e-9);
    }

    #[test]
    fn six_nd_sanity() {
        // Classic 6*N*D estimate: fwd+bwd FLOPs per token ~ 6 * params.
        let m = find_model("GPT 6.7B").unwrap();
        let tokens = m.seq_len as f64;
        let flops = m.iter_flops(1, false);
        let six_nd = 6.0 * m.total_params() as f64 * tokens;
        let ratio = flops / six_nd;
        assert!(
            (0.5..2.0).contains(&ratio),
            "iter flops {flops:.3e} vs 6ND {six_nd:.3e} (ratio {ratio:.2})"
        );
    }

    #[test]
    fn activation_accounting_positive_and_ordered() {
        let m = find_model("GPT 2.7B").unwrap();
        assert!(m.boundary_activation_bytes() > 0.0);
        // Full intra-layer activations dwarf the boundary checkpoint.
        assert!(
            m.intra_layer_activation_bytes()
                > 4.0 * m.boundary_activation_bytes()
        );
    }

    #[test]
    fn all_models_resolvable() {
        for m in table2_models() {
            assert!(find_model(&m.name).is_some());
        }
        assert!(find_model("nonexistent").is_none());
    }
}
