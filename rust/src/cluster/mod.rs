//! Heterogeneous cluster description: GPU catalog (Table 3 / Fig. 2),
//! node and cluster topology (Clusters A and B from §4.1), and the AWS
//! availability-trace generator behind Fig. 1.

pub mod aws_trace;
pub mod catalog;

use crate::configfmt::Config;
use catalog::GpuSpec;

/// One machine: a set of GPUs plus the intra-node interconnect.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub gpus: Vec<GpuSpec>,
    /// Intra-node GPU<->GPU bandwidth in Gbps (PCIe or NVLink).
    pub intra_bw_gbps: f64,
}

/// A (possibly heterogeneous) GPU cluster.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Inter-node network bandwidth in Gbps.
    pub inter_bw_gbps: f64,
}

/// Flat view of one GPU within a cluster.
#[derive(Debug, Clone)]
pub struct GpuSlot {
    pub node: usize,
    pub index_in_node: usize,
    pub spec: GpuSpec,
}

impl Cluster {
    /// All GPUs flattened in (node, slot) order — the canonical GPU
    /// indexing used by the optimizer and trainer.
    pub fn gpus(&self) -> Vec<GpuSlot> {
        let mut out = Vec::new();
        for (n, node) in self.nodes.iter().enumerate() {
            for (i, spec) in node.gpus.iter().enumerate() {
                out.push(GpuSlot {
                    node: n,
                    index_in_node: i,
                    spec: spec.clone(),
                });
            }
        }
        out
    }

    pub fn num_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.gpus.len()).sum()
    }

    /// Aggregate FP32 TFLOPs.
    pub fn total_tflops(&self) -> f64 {
        self.gpus().iter().map(|g| g.spec.tflops_fp32).sum()
    }

    /// Aggregate memory in bytes.
    pub fn total_mem_bytes(&self) -> f64 {
        self.gpus().iter().map(|g| g.spec.mem_bytes()).sum()
    }

    /// True if all GPUs share one spec.
    pub fn is_homogeneous(&self) -> bool {
        let gpus = self.gpus();
        gpus.windows(2).all(|w| w[0].spec.name == w[1].spec.name)
    }

    /// The effective all-reduce path bandwidth between two GPUs: the
    /// inter-node link if they are on different nodes, else intra-node.
    pub fn bw_between_gbps(&self, a: usize, b: usize) -> f64 {
        let gpus = self.gpus();
        if gpus[a].node == gpus[b].node {
            self.nodes[gpus[a].node].intra_bw_gbps
        } else {
            self.inter_bw_gbps
        }
    }

    /// The bottleneck bandwidth for a cluster-wide ring collective:
    /// if any two members are on different nodes, the inter-node link
    /// bounds the ring.
    pub fn ring_bw_gbps(&self) -> f64 {
        if self.nodes.len() > 1 {
            self.inter_bw_gbps
        } else {
            self.nodes[0].intra_bw_gbps
        }
    }

    /// The slowest intra-node link in the cluster — the conservative
    /// per-edge bandwidth of the runtime's same-host (shm) fast path.
    pub fn intra_bw_min_gbps(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.intra_bw_gbps)
            .fold(f64::INFINITY, f64::min)
    }

    /// §4.1 Cluster A: 2 machines (8 GPUs) over a 50 Gbps link.
    /// Machine 1: 2×L4, 1×A6000, 1×P40; machine 2: 2×P40, 2×P100.
    pub fn cluster_a() -> Cluster {
        let c = catalog::catalog();
        let g = |name: &str| c.iter().find(|s| s.name == name).unwrap().clone();
        Cluster {
            name: "A".into(),
            nodes: vec![
                Node {
                    name: "a-node0".into(),
                    gpus: vec![g("L4"), g("L4"), g("A6000"), g("P40")],
                    intra_bw_gbps: 128.0, // PCIe 4.0 x16
                },
                Node {
                    name: "a-node1".into(),
                    gpus: vec![g("P40"), g("P40"), g("P100"), g("P100")],
                    intra_bw_gbps: 96.0, // PCIe 3.0 x16
                },
            ],
            inter_bw_gbps: 50.0,
        }
    }

    /// §4.1 Cluster B: 8 VMs (64 GPUs), 100 Gbps:
    /// 2×g5.48xlarge (8×A10G each), 2×p3.16xlarge (8×V100 each),
    /// 4×g4dn.metal (8×T4 each).
    pub fn cluster_b() -> Cluster {
        let c = catalog::catalog();
        let g = |name: &str| c.iter().find(|s| s.name == name).unwrap().clone();
        let vm = |name: &str, gpu: &str, intra: f64| Node {
            name: name.into(),
            gpus: (0..8).map(|_| g(gpu)).collect(),
            intra_bw_gbps: intra,
        };
        Cluster {
            name: "B".into(),
            nodes: vec![
                vm("g5-0", "A10G", 128.0),
                vm("g5-1", "A10G", 128.0),
                vm("p3-0", "V100", 300.0), // NVLink (not all-to-all)
                vm("p3-1", "V100", 300.0),
                vm("g4dn-0", "T4", 96.0),
                vm("g4dn-1", "T4", 96.0),
                vm("g4dn-2", "T4", 96.0),
                vm("g4dn-3", "T4", 96.0),
            ],
            inter_bw_gbps: 100.0,
        }
    }

    /// Subset of Cluster B used by Fig. 6 left: only the named GPU types.
    pub fn cluster_b_subset(types: &[&str]) -> Cluster {
        let full = Self::cluster_b();
        let nodes: Vec<Node> = full
            .nodes
            .into_iter()
            .filter(|n| types.contains(&n.gpus[0].name.as_str()))
            .collect();
        assert!(!nodes.is_empty(), "no nodes matched {types:?}");
        Cluster {
            name: format!("B[{}]", types.join("+")),
            nodes,
            inter_bw_gbps: 100.0,
        }
    }

    /// Homogeneous comparison cluster (Fig. 6 right: 32×A10G; Fig. 8:
    /// 16×V100).
    pub fn homogeneous(gpu: &str, count: usize, per_node: usize,
                       inter_bw_gbps: f64) -> Cluster {
        let c = catalog::catalog();
        let spec = c
            .iter()
            .find(|s| s.name == gpu)
            .unwrap_or_else(|| panic!("unknown GPU '{gpu}'"))
            .clone();
        assert!(count % per_node == 0);
        let nodes = (0..count / per_node)
            .map(|i| Node {
                name: format!("{gpu}-node{i}"),
                gpus: vec![spec.clone(); per_node],
                intra_bw_gbps: 128.0,
            })
            .collect();
        Cluster {
            name: format!("{count}x{gpu}"),
            nodes,
            inter_bw_gbps,
        }
    }

    /// Look up a named preset cluster.
    pub fn preset(name: &str) -> Option<Cluster> {
        match name.to_ascii_lowercase().as_str() {
            "a" | "cluster-a" => Some(Self::cluster_a()),
            "b" | "cluster-b" => Some(Self::cluster_b()),
            // p3.16xlarge VMs expose 25 Gbps NICs (the Fig.-8 testbed).
            "16xv100" => Some(Self::homogeneous("V100", 16, 8, 25.0)),
            "32xa10g" => Some(Self::homogeneous("A10G", 32, 8, 100.0)),
            _ => None,
        }
    }

    /// Build a cluster from a parsed TOML config (see `configs/*.toml`).
    pub fn from_config(cfg: &Config) -> Result<Cluster, String> {
        let cat = catalog::catalog();
        let name = cfg.str("cluster.name").unwrap_or("custom").to_string();
        let inter = cfg
            .f64("cluster.inter_bw_gbps")
            .ok_or("missing cluster.inter_bw_gbps")?;
        let n_nodes = cfg.table_count("node");
        if n_nodes == 0 {
            return Err("config defines no [[node]] blocks".into());
        }
        let mut nodes = Vec::new();
        for i in 0..n_nodes {
            let gpus_val = cfg
                .get(&format!("node[{i}].gpus"))
                .and_then(|v| v.as_array())
                .ok_or(format!("node[{i}] missing gpus array"))?;
            let mut gpus = Vec::new();
            for v in gpus_val {
                let gname = v.as_str().ok_or("gpu names must be strings")?;
                let spec = cat
                    .iter()
                    .find(|s| s.name == gname)
                    .ok_or(format!("unknown GPU type '{gname}'"))?;
                gpus.push(spec.clone());
            }
            let intra = cfg
                .f64(&format!("node[{i}].intra_bw_gbps"))
                .unwrap_or(96.0);
            nodes.push(Node {
                name: format!("node{i}"),
                gpus,
                intra_bw_gbps: intra,
            });
        }
        Ok(Cluster { name, nodes, inter_bw_gbps: inter })
    }
}

/// Convert Gbps to bytes/second.
pub fn gbps_to_bytes_per_sec(gbps: f64) -> f64 {
    gbps * 1e9 / 8.0
}

/// Peak aggregate TFLOPs check used in Fig. 6 right (984 vs 998).
pub fn peak_tflops_close(a: &Cluster, b: &Cluster, tol_frac: f64) -> bool {
    let (ta, tb) = (a.total_tflops(), b.total_tflops());
    ((ta - tb) / tb).abs() <= tol_frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_a_composition() {
        let a = Cluster::cluster_a();
        assert_eq!(a.num_gpus(), 8);
        let counts = |name: &str| {
            a.gpus().iter().filter(|g| g.spec.name == name).count()
        };
        assert_eq!(counts("L4"), 2);
        assert_eq!(counts("A6000"), 1);
        assert_eq!(counts("P40"), 3);
        assert_eq!(counts("P100"), 2);
        assert_eq!(a.inter_bw_gbps, 50.0);
        assert!(!a.is_homogeneous());
    }

    #[test]
    fn cluster_b_composition() {
        let b = Cluster::cluster_b();
        assert_eq!(b.num_gpus(), 64);
        let counts = |name: &str| {
            b.gpus().iter().filter(|g| g.spec.name == name).count()
        };
        assert_eq!(counts("A10G"), 16);
        assert_eq!(counts("V100"), 16);
        assert_eq!(counts("T4"), 32);
        assert_eq!(b.inter_bw_gbps, 100.0);
    }

    #[test]
    fn fig6_homogeneous_comparison_is_matched() {
        // Paper: Cluster B (998 TFLOPs) vs 32xA10G (984 TFLOPs).
        let b = Cluster::cluster_b();
        let homo = Cluster::homogeneous("A10G", 32, 8, 100.0);
        assert!(peak_tflops_close(&b, &homo, 0.05));
        assert!(homo.is_homogeneous());
    }

    #[test]
    fn subset_selection() {
        let s = Cluster::cluster_b_subset(&["A10G"]);
        assert_eq!(s.num_gpus(), 16);
        let s2 = Cluster::cluster_b_subset(&["A10G", "V100"]);
        assert_eq!(s2.num_gpus(), 32);
    }

    #[test]
    fn gpu_flat_indexing_is_stable() {
        let a = Cluster::cluster_a();
        let gpus = a.gpus();
        assert_eq!(gpus[0].spec.name, "L4");
        assert_eq!(gpus[3].spec.name, "P40");
        assert_eq!(gpus[3].node, 0);
        assert_eq!(gpus[4].node, 1);
    }

    #[test]
    fn bandwidth_lookup() {
        let a = Cluster::cluster_a();
        assert_eq!(a.bw_between_gbps(0, 1), 128.0); // same node
        assert_eq!(a.bw_between_gbps(0, 7), 50.0); // cross node
        assert_eq!(a.ring_bw_gbps(), 50.0);
    }

    #[test]
    fn from_config_roundtrip() {
        let text = r#"
[cluster]
name = "mini"
inter_bw_gbps = 25.0

[[node]]
gpus = ["T4", "V100"]
intra_bw_gbps = 64.0
"#;
        let cfg = Config::parse(text).unwrap();
        let c = Cluster::from_config(&cfg).unwrap();
        assert_eq!(c.num_gpus(), 2);
        assert_eq!(c.gpus()[1].spec.name, "V100");
        assert_eq!(c.inter_bw_gbps, 25.0);
    }

    #[test]
    fn from_config_rejects_unknown_gpu() {
        let text = "[cluster]\ninter_bw_gbps = 1.0\n[[node]]\ngpus = [\"NOPE\"]";
        let cfg = Config::parse(text).unwrap();
        assert!(Cluster::from_config(&cfg).is_err());
    }

    #[test]
    fn presets_resolve() {
        assert!(Cluster::preset("a").is_some());
        assert!(Cluster::preset("B").is_some());
        assert!(Cluster::preset("16xV100".to_lowercase().as_str()).is_some());
        assert!(Cluster::preset("nope").is_none());
    }
}
