//! Synthetic AWS GPU-availability trace (Fig. 1 substitute).
//!
//! The paper plots hourly availability of GPU VM types in us-west over a
//! 12-hour window: A100/H100 nearly always unavailable, mid-tier (A10G,
//! V100, T4) limited. We cannot re-run their crawler, so this module
//! generates a seeded trace with the same qualitative profile: a per-type
//! base availability level, diurnal modulation and bursty stock-outs.
//! Deterministic given the seed — the Fig.-1 bench regenerates the same
//! series every run.

use crate::util::prng::Rng;

/// Availability profile for one instance type.
#[derive(Debug, Clone)]
pub struct TypeProfile {
    pub gpu: String,
    /// Mean fraction of requested capacity that is grantable (0..1).
    pub base_availability: f64,
    /// Maximum instances a single account can typically obtain.
    pub quota_cap: u32,
}

/// Paper-calibrated profiles: high-end nearly zero, mid-tier limited.
pub fn default_profiles() -> Vec<TypeProfile> {
    vec![
        TypeProfile { gpu: "H100".into(), base_availability: 0.02, quota_cap: 2 },
        TypeProfile { gpu: "A100".into(), base_availability: 0.05, quota_cap: 4 },
        TypeProfile { gpu: "A10G".into(), base_availability: 0.45, quota_cap: 16 },
        TypeProfile { gpu: "V100".into(), base_availability: 0.40, quota_cap: 16 },
        TypeProfile { gpu: "T4".into(), base_availability: 0.65, quota_cap: 32 },
        TypeProfile { gpu: "K80".into(), base_availability: 0.90, quota_cap: 32 },
    ]
}

/// One hourly sample: instances obtainable for each type.
#[derive(Debug, Clone)]
pub struct HourSample {
    pub hour: usize,
    pub available: Vec<(String, u32)>,
}

/// Generate an `hours`-long trace (Fig. 1 uses 12).
pub fn generate(seed: u64, hours: usize, profiles: &[TypeProfile])
    -> Vec<HourSample> {
    let mut rng = Rng::new(seed);
    // Per-type burst state: stock-outs persist for a few hours.
    let mut stockout: Vec<usize> = vec![0; profiles.len()];
    let mut out = Vec::with_capacity(hours);
    for hour in 0..hours {
        let mut available = Vec::with_capacity(profiles.len());
        // Mild diurnal demand wave: availability dips mid-trace.
        let diurnal = 1.0
            - 0.25
                * (std::f64::consts::PI * hour as f64 / hours.max(1) as f64)
                    .sin();
        for (i, p) in profiles.iter().enumerate() {
            if stockout[i] > 0 {
                stockout[i] -= 1;
                available.push((p.gpu.clone(), 0));
                continue;
            }
            // Chance of entering a stock-out burst is higher for scarce
            // types.
            if rng.bool((1.0 - p.base_availability) * 0.3) {
                stockout[i] = rng.range(1, 4);
                available.push((p.gpu.clone(), 0));
                continue;
            }
            let level = (p.base_availability * diurnal
                * (0.6 + 0.8 * rng.f64()))
            .clamp(0.0, 1.0);
            let count = (level * p.quota_cap as f64).round() as u32;
            available.push((p.gpu.clone(), count.min(p.quota_cap)));
        }
        out.push(HourSample { hour, available });
    }
    out
}

/// Fold one trace hour onto a cluster-membership size in
/// `[min_gpus, max_gpus]`: the total obtainable instances across all
/// types, folded into the membership range. Deterministic, and —
/// because hourly availability oscillates between a handful of levels
/// (Fig. 1) — recurring, which is what makes the elastic `PlanCache`
/// pay off on a live session.
pub fn membership_size(
    hour: &HourSample,
    min_gpus: usize,
    max_gpus: usize,
) -> usize {
    assert!(min_gpus >= 1 && min_gpus <= max_gpus);
    let total: u32 =
        hour.available.iter().map(|(_, c)| *c).sum();
    min_gpus + total as usize % (max_gpus - min_gpus + 1)
}

/// Fraction of hours with zero availability for `gpu`.
pub fn unavailability_fraction(trace: &[HourSample], gpu: &str) -> f64 {
    let zero_hours = trace
        .iter()
        .filter(|h| {
            h.available
                .iter()
                .any(|(g, c)| g == gpu && *c == 0)
        })
        .count();
    zero_hours as f64 / trace.len().max(1) as f64
}

/// Mean available instances for `gpu` over the trace.
pub fn mean_available(trace: &[HourSample], gpu: &str) -> f64 {
    let total: u32 = trace
        .iter()
        .flat_map(|h| h.available.iter())
        .filter(|(g, _)| g == gpu)
        .map(|(_, c)| *c)
        .sum();
    total as f64 / trace.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let p = default_profiles();
        let a = generate(42, 12, &p);
        let b = generate(42, 12, &p);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.available, y.available);
        }
    }

    #[test]
    fn high_end_scarcer_than_mid_tier() {
        let p = default_profiles();
        let trace = generate(7, 240, &p);
        let h100_unavail = unavailability_fraction(&trace, "H100");
        let t4_unavail = unavailability_fraction(&trace, "T4");
        assert!(
            h100_unavail > 0.7,
            "H100 should be mostly unavailable, got {h100_unavail}"
        );
        assert!(t4_unavail < 0.5, "T4 too scarce: {t4_unavail}");
        assert!(mean_available(&trace, "T4") > mean_available(&trace, "A100"));
    }

    #[test]
    fn trace_length_and_types() {
        let p = default_profiles();
        let trace = generate(1, 12, &p);
        assert_eq!(trace.len(), 12);
        for h in &trace {
            assert_eq!(h.available.len(), p.len());
        }
    }

    #[test]
    fn membership_sizes_stay_in_range_and_recur() {
        let p = default_profiles();
        let trace = generate(5, 40, &p);
        let sizes: Vec<usize> =
            trace.iter().map(|h| membership_size(h, 6, 8)).collect();
        assert!(sizes.iter().all(|&s| (6..=8).contains(&s)));
        // 40 events over 3 possible memberships: recurrence guaranteed,
        // and the generator should actually exercise churn (≥2 sizes).
        let distinct: std::collections::BTreeSet<_> =
            sizes.iter().collect();
        assert!(distinct.len() >= 2, "trace produced no churn: {sizes:?}");
        // Degenerate single-size range collapses deterministically.
        assert!(trace.iter().all(|h| membership_size(h, 4, 4) == 4));
    }

    #[test]
    fn counts_respect_quota() {
        let p = default_profiles();
        let trace = generate(3, 100, &p);
        for h in &trace {
            for (g, c) in &h.available {
                let prof = p.iter().find(|x| &x.gpu == g).unwrap();
                assert!(*c <= prof.quota_cap);
            }
        }
    }
}
