//! GPU specification catalog.
//!
//! Table 3 of the paper plus the additional models appearing in Fig. 2's
//! TFLOPs-vs-memory scatter and Fig. 1's availability trace. All numbers
//! are vendor FP32 peak (no tensor cores) and marketing memory capacity,
//! matching the paper's usage.

use crate::util::GB;

/// Static description of one GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    pub generation: String,
    /// Memory capacity in GB (decimal, as marketed / Table 3).
    pub mem_gb: f64,
    /// Peak FP32 TFLOPs (Table 3).
    pub tflops_fp32: f64,
}

impl GpuSpec {
    pub fn new(name: &str, generation: &str, mem_gb: f64, tflops: f64)
        -> Self {
        Self {
            name: name.into(),
            generation: generation.into(),
            mem_gb,
            tflops_fp32: tflops,
        }
    }

    /// Memory capacity in bytes.
    pub fn mem_bytes(&self) -> f64 {
        self.mem_gb * GB
    }

    /// Peak FLOP/s.
    pub fn flops(&self) -> f64 {
        self.tflops_fp32 * 1e12
    }

    /// Compute-to-memory ratio (TFLOPs per GB) — the heterogeneity axis
    /// the paper's Fig. 2 highlights (L4 vs P40 etc.).
    pub fn compute_mem_ratio(&self) -> f64 {
        self.tflops_fp32 / self.mem_gb
    }
}

/// Table 3 GPUs (clusters A and B) + Fig. 2 extras.
pub fn catalog() -> Vec<GpuSpec> {
    vec![
        // Cluster A (Table 3)
        GpuSpec::new("P40", "Pascal", 24.0, 11.8),
        GpuSpec::new("P100", "Pascal", 12.0, 9.3),
        GpuSpec::new("A6000", "Ampere", 48.0, 38.7),
        GpuSpec::new("L4", "Ada", 24.0, 30.3),
        // Cluster B (Table 3)
        GpuSpec::new("V100", "Volta", 16.0, 14.1),
        GpuSpec::new("T4", "Turing", 15.0, 8.1),
        GpuSpec::new("A10G", "Ampere", 24.0, 31.2),
        // Fig. 1 / Fig. 2 extras
        GpuSpec::new("A100", "Ampere", 80.0, 19.5),
        GpuSpec::new("H100", "Hopper", 80.0, 66.9),
        GpuSpec::new("K80", "Kepler", 12.0, 4.1),
        GpuSpec::new("M60", "Maxwell", 8.0, 4.8),
        GpuSpec::new("RTX6000", "Turing", 24.0, 16.3),
    ]
}

/// Lookup by name (case-sensitive, as in Table 3).
pub fn find(name: &str) -> Option<GpuSpec> {
    catalog().into_iter().find(|g| g.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values_exact() {
        let p40 = find("P40").unwrap();
        assert_eq!(p40.mem_gb, 24.0);
        assert_eq!(p40.tflops_fp32, 11.8);
        assert_eq!(p40.generation, "Pascal");
        let a6000 = find("A6000").unwrap();
        assert_eq!(a6000.mem_gb, 48.0);
        assert_eq!(a6000.tflops_fp32, 38.7);
        let t4 = find("T4").unwrap();
        assert_eq!(t4.mem_gb, 15.0);
        assert_eq!(t4.tflops_fp32, 8.1);
    }

    #[test]
    fn fig2_mismatch_examples() {
        // The paper's motivating mismatch: L4 is ~2.6x faster than P40
        // at the SAME memory capacity.
        let l4 = find("L4").unwrap();
        let p40 = find("P40").unwrap();
        assert_eq!(l4.mem_gb, p40.mem_gb);
        assert!(l4.tflops_fp32 / p40.tflops_fp32 > 2.0);
        // And V100 vs T4: similar memory, very different compute (§4.3).
        let v100 = find("V100").unwrap();
        let t4 = find("T4").unwrap();
        assert!((v100.mem_gb - t4.mem_gb).abs() <= 1.0);
        assert!(v100.tflops_fp32 > 1.5 * t4.tflops_fp32);
    }

    #[test]
    fn bytes_conversion() {
        let t4 = find("T4").unwrap();
        assert_eq!(t4.mem_bytes(), 15.0 * GB);
        assert_eq!(t4.flops(), 8.1e12);
    }

    #[test]
    fn catalog_has_no_duplicates() {
        let c = catalog();
        for (i, a) in c.iter().enumerate() {
            for b in &c[i + 1..] {
                assert_ne!(a.name, b.name);
            }
        }
    }

    #[test]
    fn compute_mem_ratio_ordering() {
        // L4 has one of the highest compute:memory ratios in the catalog;
        // P100 is mid; K80 is low.
        let l4 = find("L4").unwrap().compute_mem_ratio();
        let k80 = find("K80").unwrap().compute_mem_ratio();
        assert!(l4 > 3.0 * k80);
    }
}
