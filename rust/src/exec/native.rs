//! Dependency-free training backend: real f32 gradients of a small
//! built-in differentiable model, exact under resharding.
//!
//! The surrogate is an embedding-regression quadratic: parameters are a
//! `vocab x dim` table plus a shared `dim` bias; each token position
//! predicts `p = table[token] + bias` and pays the least-squares loss
//! `0.5 * ||p - v(target)||^2` against a fixed dyadic target vector
//! derived from the Markov corpus's next token. The gradient is the
//! textbook residual `p - v(target)`, so the loss descends toward the
//! chain's conditional mean — a meaningful curve with zero external
//! dependencies.
//!
//! ## Why gradients are quantized (and why that buys bitwise elasticity)
//!
//! f32 addition is not associative, so an FSDP gradient sum normally
//! depends on how the batch was split across workers and on the ring
//! schedule — which would make "params after a migration match a
//! single-worker reference" only approximately true. This backend
//! quantizes every per-token gradient contribution onto the dyadic grid
//! `k / 256` with `|k| <= 2048` (see [`quantize`]). All partial sums of
//! up to [`MAX_STEP_TOKENS`] such terms are integers `<= 2^24` in grid
//! units, which f32 represents EXACTLY — so gradient summation becomes
//! associative and commutative, and any worker split, ring order or
//! shard layout produces bit-identical totals. That is the property the
//! live elastic session's acceptance test leans on.

use crate::perfmodel::ComputeOracle;
use crate::util::error::{anyhow, Result};

use super::{StepExecutor, StepOutput, UnitStepOutput};

/// Gradient grid: contributions are multiples of 1/256, clamped to
/// [-8, 8] (so `k/256` with `|k| <= 2048`).
const GRID: f32 = 256.0;
const CLAMP_UNITS: f32 = 2048.0;

/// Max tokens in one step such that every partial gradient sum stays
/// exactly representable: tokens * 8 * 256 <= 2^24.
pub const MAX_STEP_TOKENS: usize = 8192;

/// Snap a gradient contribution onto the exact-summation grid.
#[inline]
fn quantize(g: f32) -> f32 {
    (g * GRID).round().clamp(-CLAMP_UNITS, CLAMP_UNITS) / GRID
}

/// Dyadic regression target for (next-token, component): multiples of
/// 1/16 in [-0.5, 0.5], exactly representable.
#[inline]
fn target_value(target: i32, j: usize) -> f32 {
    let k = (target as i64 * (j as i64 + 1)).rem_euclid(17);
    k as f32 / 16.0 - 0.5
}

/// Shape of the built-in surrogate model.
#[derive(Debug, Clone)]
pub struct SurrogateSpec {
    pub vocab: usize,
    pub dim: usize,
    pub seq_len: usize,
}

impl Default for SurrogateSpec {
    fn default() -> Self {
        Self { vocab: 64, dim: 32, seq_len: 16 }
    }
}

/// Simulated per-step durations for the timing hook: worker i's share
/// of `b_i` samples costs `b_i * per_sample_seconds[i]`; the step takes
/// the slowest worker plus a fixed collective term. Built from the same
/// `SyntheticOracle` the planner profiled, so reported steps/sec track
/// the planned heterogeneity.
#[derive(Debug, Clone)]
pub struct StepTimeModel {
    pub per_sample_seconds: Vec<f64>,
    pub fixed_seconds: f64,
}

impl StepTimeModel {
    /// Per-sample cost from the oracle: one fwd+bwd layer pass at m=1,
    /// times the layer count.
    pub fn from_oracle(
        oracle: &(dyn ComputeOracle + Sync),
        layers: usize,
    ) -> StepTimeModel {
        let per_sample_seconds = (0..oracle.num_gpus())
            .map(|g| {
                (oracle.fwd_latency(g, 1) + oracle.bwd_latency(g, 1))
                    * layers as f64
            })
            .collect();
        StepTimeModel { per_sample_seconds, fixed_seconds: 0.0 }
    }

    /// Planned per-rank duration of one step: worker i's share costs
    /// `b_i * per_sample_seconds[i]` plus the fixed collective term.
    /// This is the PLANNED side of the coordinator's skew report —
    /// compared against per-rank measured phase totals.
    pub fn per_rank_seconds(&self, batches: &[usize]) -> Vec<f64> {
        batches
            .iter()
            .zip(&self.per_sample_seconds)
            .map(|(&b, &s)| b as f64 * s + self.fixed_seconds)
            .collect()
    }

    /// Simulated duration of one step with the given batch shares
    /// (workers are indexed against the model's GPU order; prefix
    /// memberships use a prefix of it).
    pub fn step_seconds(&self, batches: &[usize]) -> f64 {
        let slowest = batches
            .iter()
            .zip(&self.per_sample_seconds)
            .map(|(&b, &s)| b as f64 * s)
            .fold(0.0f64, f64::max);
        slowest + self.fixed_seconds
    }
}

/// The dependency-free backend. See the module docs for the model and
/// the exact-summation contract.
pub struct NativeExecutor {
    spec: SurrogateSpec,
    sizes: Vec<usize>,
    timer: Option<StepTimeModel>,
}

impl NativeExecutor {
    pub fn new(spec: SurrogateSpec) -> NativeExecutor {
        assert!(spec.vocab >= 2 && spec.dim >= 1 && spec.seq_len >= 1);
        let sizes = vec![spec.vocab * spec.dim, spec.dim];
        NativeExecutor { spec, sizes, timer: None }
    }

    /// Attach simulated step durations (the `SyntheticOracle` timing
    /// hook); without one, wall time is reported.
    pub fn with_timer(mut self, timer: StepTimeModel) -> NativeExecutor {
        self.timer = Some(timer);
        self
    }

    pub fn spec(&self) -> &SurrogateSpec {
        &self.spec
    }

    /// One worker's pass: accumulate quantized per-token gradients into
    /// a full-length flat vector; returns (grads, loss_sum, tokens).
    fn worker_pass(
        &self,
        table: &[f32],
        bias: &[f32],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(Vec<f32>, f64, f64)> {
        let d = self.spec.dim;
        let v = self.spec.vocab;
        let mut g = vec![0f32; v * d + d];
        let mut loss = 0f64;
        for (&x, &y) in tokens.iter().zip(targets) {
            let xi = x as usize;
            if x < 0 || xi >= v {
                return Err(anyhow!("token {x} outside vocab {v}"));
            }
            let row = xi * d;
            for j in 0..d {
                let r = table[row + j] + bias[j] - target_value(y, j);
                loss += 0.5 * (r as f64) * (r as f64);
                let q = quantize(r);
                g[row + j] += q;
                g[v * d + j] += q;
            }
        }
        Ok((g, loss, tokens.len() as f64))
    }

    /// One worker's unit pass over a token chunk: accumulate the
    /// quantized gradients of the tokens whose embedding row lies in
    /// `rows` into the caller-provided `unit_g` (unit-local layout) and
    /// `tail_g` (bias); returns the f64 loss of the touched tokens.
    /// Chunking the token axis lets the distributed step drive a
    /// prefetch AllGather round between chunks — summation stays exact
    /// on the dyadic grid, so the chunk size never changes a bit.
    pub fn unit_pass_chunk(
        &self,
        rows: std::ops::Range<usize>,
        unit_params: &[f32],
        bias: &[f32],
        tokens: &[i32],
        targets: &[i32],
        unit_g: &mut [f32],
        tail_g: &mut [f32],
    ) -> Result<f64> {
        let d = self.spec.dim;
        let v = self.spec.vocab;
        let mut loss = 0f64;
        for (&x, &y) in tokens.iter().zip(targets) {
            let xi = x as usize;
            if x < 0 || xi >= v {
                return Err(anyhow!("token {x} outside vocab {v}"));
            }
            if !rows.contains(&xi) {
                continue;
            }
            let base = (xi - rows.start) * d;
            for j in 0..d {
                let r =
                    unit_params[base + j] + bias[j] - target_value(y, j);
                loss += 0.5 * (r as f64) * (r as f64);
                let q = quantize(r);
                unit_g[base + j] += q;
                tail_g[j] += q;
            }
        }
        Ok(loss)
    }

    fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != 2
            || params[0].len() != self.sizes[0]
            || params[1].len() != self.sizes[1]
        {
            return Err(anyhow!(
                "params do not match the surrogate shape \
                 [{} x {}, {}]",
                self.spec.vocab,
                self.spec.dim,
                self.spec.dim
            ));
        }
        Ok(())
    }
}

impl StepExecutor for NativeExecutor {
    fn name(&self) -> &'static str {
        "native"
    }

    fn param_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn vocab(&self) -> usize {
        self.spec.vocab
    }

    fn seq_len(&self) -> usize {
        self.spec.seq_len
    }

    fn init_params(&self, seed: u64) -> Vec<Vec<f32>> {
        // Table ~ N(0, 0.02), bias zero — the same convention as the
        // PJRT manifest init (weights random, biases zero).
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut table = vec![0f32; self.sizes[0]];
        rng.fill_normal(&mut table, 0.02);
        vec![table, vec![0f32; self.sizes[1]]]
    }

    fn run_step(
        &mut self,
        params: &[Vec<f32>],
        parts: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<StepOutput> {
        self.check_params(params)?;
        let seq = self.spec.seq_len;
        let total_tokens: usize =
            parts.iter().map(|(t, _)| t.len()).sum();
        if total_tokens == 0 {
            return Err(anyhow!("empty step: no worker has any rows"));
        }
        if total_tokens > MAX_STEP_TOKENS {
            return Err(anyhow!(
                "{total_tokens} tokens/step exceeds the exact-summation \
                 bound {MAX_STEP_TOKENS} (shrink batch or seq_len)"
            ));
        }
        for (tokens, targets) in parts {
            if tokens.len() != targets.len() || tokens.len() % seq != 0 {
                return Err(anyhow!("malformed batch share"));
            }
        }
        let table = &params[0];
        let bias = &params[1];
        // One scoped thread per worker, joined in rank order so the f64
        // loss accumulation stays deterministic.
        let this: &NativeExecutor = self;
        let sp = crate::telemetry::span(
            crate::telemetry::CAT_COMPUTE,
            "native step",
        );
        let results: Vec<Result<(Vec<f32>, f64, f64)>> =
            std::thread::scope(|scope| {
                parts
                    .iter()
                    .map(|(tokens, targets)| {
                        scope.spawn(move || {
                            this.worker_pass(table, bias, tokens, targets)
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|j| j.join().unwrap())
                    .collect()
            });
        drop(sp);
        let mut worker_grads = Vec::with_capacity(parts.len());
        let mut loss_sum = 0f64;
        let mut token_count = 0f64;
        for r in results {
            let (g, ls, cnt) = r?;
            worker_grads.push(g);
            loss_sum += ls;
            token_count += cnt;
        }
        Ok(StepOutput { worker_grads, loss_sum, token_count })
    }

    fn step_seconds(&self, batches: &[usize], measured_wall: f64) -> f64 {
        match &self.timer {
            Some(t) => t.step_seconds(batches),
            None => measured_wall,
        }
    }

    fn unit_region(&self) -> usize {
        self.sizes[0]
    }

    fn unit_alignment(&self) -> usize {
        self.spec.dim
    }

    fn run_unit_step(
        &mut self,
        unit: std::ops::Range<usize>,
        unit_params: &[f32],
        tail: &[f32],
        parts: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<UnitStepOutput> {
        let d = self.spec.dim;
        let region = self.sizes[0];
        if unit.start > unit.end
            || unit.end > region
            || unit.start % d != 0
            || unit.end % d != 0
        {
            return Err(anyhow!(
                "unit [{}, {}) is not a row-aligned slice of the \
                 {region}-element table",
                unit.start,
                unit.end
            ));
        }
        if unit_params.len() != unit.len() || tail.len() != self.sizes[1] {
            return Err(anyhow!(
                "unit/tail params do not match the unit shape \
                 ({} + {} elems, wanted {} + {})",
                unit_params.len(),
                tail.len(),
                unit.len(),
                self.sizes[1]
            ));
        }
        let seq = self.spec.seq_len;
        let total_tokens: usize =
            parts.iter().map(|(t, _)| t.len()).sum();
        if total_tokens > MAX_STEP_TOKENS {
            return Err(anyhow!(
                "{total_tokens} tokens/step exceeds the exact-summation \
                 bound {MAX_STEP_TOKENS} (shrink batch or seq_len)"
            ));
        }
        for (tokens, targets) in parts {
            if tokens.len() != targets.len() || tokens.len() % seq != 0 {
                return Err(anyhow!("malformed batch share"));
            }
        }
        let rows = unit.start / d..unit.end / d;
        // Same worker-thread shape as `run_step`, joined in rank order
        // so the f64 loss stays deterministic.
        let this: &NativeExecutor = self;
        let sp = crate::telemetry::span(
            crate::telemetry::CAT_COMPUTE,
            "native unit step",
        );
        let results: Vec<Result<(Vec<f32>, Vec<f32>, f64)>> =
            std::thread::scope(|scope| {
                parts
                    .iter()
                    .map(|(tokens, targets)| {
                        let rows = rows.clone();
                        scope.spawn(move || {
                            let mut ug = vec![0f32; unit_params.len()];
                            let mut bg = vec![0f32; tail.len()];
                            let loss = this.unit_pass_chunk(
                                rows,
                                unit_params,
                                tail,
                                tokens,
                                targets,
                                &mut ug,
                                &mut bg,
                            )?;
                            Ok((ug, bg, loss))
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|j| j.join().unwrap())
                    .collect()
            });
        drop(sp);
        let mut worker_unit_grads = Vec::with_capacity(parts.len());
        let mut worker_tail_grads = Vec::with_capacity(parts.len());
        let mut loss_sum = 0f64;
        for r in results {
            let (ug, bg, ls) = r?;
            worker_unit_grads.push(ug);
            worker_tail_grads.push(bg);
            loss_sum += ls;
        }
        Ok(UnitStepOutput { worker_unit_grads, worker_tail_grads, loss_sum })
    }

    fn eval_loss(
        &mut self,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, f64)> {
        self.check_params(params)?;
        let d = self.spec.dim;
        let v = self.spec.vocab;
        let mut loss = 0f64;
        for (&x, &y) in tokens.iter().zip(targets) {
            let xi = x as usize;
            if x < 0 || xi >= v {
                return Err(anyhow!("token {x} outside vocab {v}"));
            }
            for j in 0..d {
                let r = params[0][xi * d + j] + params[1][j]
                    - target_value(y, j);
                loss += 0.5 * (r as f64) * (r as f64);
            }
        }
        Ok((loss, tokens.len() as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::data::{split_batch, Corpus};

    fn sample(batch: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let spec = SurrogateSpec::default();
        let mut corpus = Corpus::new(spec.vocab, 4, seed);
        corpus.sample_batch(batch, spec.seq_len)
    }

    /// Elementwise f32 sum of worker gradients in the given rank order.
    fn sum_grads(grads: &[Vec<f32>]) -> Vec<f32> {
        let mut out = vec![0f32; grads[0].len()];
        for g in grads {
            for (o, x) in out.iter_mut().zip(g) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn quantize_is_exact_on_the_grid() {
        assert_eq!(quantize(0.0), 0.0);
        assert_eq!(quantize(1.0), 1.0);
        assert_eq!(quantize(100.0), 8.0); // clamp
        assert_eq!(quantize(-100.0), -8.0);
        // 3/256 snaps to itself; midpoints round deterministically.
        let g = 3.0 / 256.0;
        assert_eq!(quantize(g), g);
        // Result is always k/256 with integer k.
        for &x in &[0.1f32, -0.37, 2.7182, 7.99, -7.99] {
            let q = quantize(x);
            assert_eq!((q * 256.0).fract(), 0.0, "{x} -> {q}");
            assert!((q - x).abs() <= 0.5 / 256.0 + 1e-6);
        }
    }

    #[test]
    fn target_values_are_dyadic_and_bounded() {
        for y in 0..64i32 {
            for j in 0..32usize {
                let t = target_value(y, j);
                assert!((-0.5..=0.5).contains(&t));
                assert_eq!((t * 16.0).fract(), 0.0, "non-dyadic {t}");
            }
        }
    }

    #[test]
    fn worker_splits_sum_bitwise_identically() {
        // The exact-summation contract: any batch split produces the
        // same gradient total, bit for bit, in any summation order.
        let mut exec = NativeExecutor::new(SurrogateSpec::default());
        let params = exec.init_params(3);
        let seq = exec.seq_len();
        let (tokens, targets) = sample(8, 5);
        let splits: [&[usize]; 3] = [&[8], &[3, 5], &[1, 1, 6]];
        let mut totals: Vec<Vec<f32>> = Vec::new();
        for sizes in splits {
            let parts = split_batch(&tokens, &targets, seq, sizes);
            let out = exec.run_step(&params, &parts).unwrap();
            assert_eq!(out.worker_grads.len(), sizes.len());
            assert_eq!(out.token_count, 8.0 * seq as f64);
            totals.push(sum_grads(&out.worker_grads));
            // Reversed summation order must not change a single bit.
            let mut rev = out.worker_grads.clone();
            rev.reverse();
            assert_eq!(sum_grads(&rev), *totals.last().unwrap());
        }
        assert_eq!(totals[0], totals[1]);
        assert_eq!(totals[1], totals[2]);
    }

    #[test]
    fn gradients_descend_the_surrogate_loss() {
        // Deterministic corpus (branch 1): the surrogate's least-squares
        // optimum has near-zero irreducible loss, so SGD on the
        // quantized gradients must drive the fixed-batch loss way down.
        let spec = SurrogateSpec::default();
        let mut exec = NativeExecutor::new(spec.clone());
        let mut params = exec.init_params(7);
        let seq = exec.seq_len();
        let mut corpus = Corpus::new(spec.vocab, 1, 9);
        let (tokens, targets) = corpus.sample_batch(16, seq);
        let parts = split_batch(&tokens, &targets, seq, &[16]);
        let first = exec.run_step(&params, &parts).unwrap();
        // Plain SGD on the quantized gradients (Eq.-1 scaling).
        for _ in 0..300 {
            let out = exec.run_step(&params, &parts).unwrap();
            let inv = 1.0 / out.token_count as f32;
            let g = &out.worker_grads[0];
            let mut off = 0;
            for p in params.iter_mut() {
                for (pi, gi) in p.iter_mut().zip(&g[off..]) {
                    *pi -= gi * inv; // lr = 1.0
                }
                off += p.len();
            }
        }
        let last = exec.run_step(&params, &parts).unwrap();
        assert!(
            last.loss_sum < 0.5 * first.loss_sum,
            "loss did not descend: {} -> {}",
            first.loss_sum,
            last.loss_sum
        );
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut exec = NativeExecutor::new(SurrogateSpec::default());
        let params = exec.init_params(1);
        // Out-of-vocab token.
        let bad = vec![(vec![999i32; 16], vec![0i32; 16])];
        assert!(exec.run_step(&params, &bad).is_err());
        // Empty step.
        let empty = vec![(Vec::new(), Vec::new())];
        assert!(exec.run_step(&params, &empty).is_err());
        // Wrong param shape.
        let wrong = vec![vec![0f32; 3]];
        let good = vec![(vec![0i32; 16], vec![0i32; 16])];
        assert!(exec.run_step(&wrong, &good).is_err());
        // Token budget.
        let spec = exec.spec().clone();
        let rows = MAX_STEP_TOKENS / spec.seq_len + 1;
        let huge = vec![(
            vec![0i32; rows * spec.seq_len],
            vec![0i32; rows * spec.seq_len],
        )];
        assert!(exec.run_step(&params, &huge).is_err());
    }

    #[test]
    fn timer_substitutes_simulated_durations() {
        let timer = StepTimeModel {
            per_sample_seconds: vec![0.5, 0.1],
            fixed_seconds: 0.25,
        };
        assert_eq!(timer.step_seconds(&[2, 8]), 1.0 + 0.25);
        let exec = NativeExecutor::new(SurrogateSpec::default())
            .with_timer(timer);
        assert_eq!(exec.step_seconds(&[2, 8], 99.0), 1.25);
    }

    #[test]
    fn unit_steps_reassemble_the_whole_step_bitwise() {
        // Invariant 13 at the executor level: cutting the table into
        // row-aligned units, running each unit's slice of the step and
        // reassembling (concat unit grads, sum tail partials) must be
        // bitwise the monolithic step's gradients.
        let mut exec = NativeExecutor::new(SurrogateSpec::default());
        let params = exec.init_params(11);
        let seq = exec.seq_len();
        let (tokens, targets) = sample(6, 13);
        let parts = split_batch(&tokens, &targets, seq, &[2, 4]);
        let whole = exec.run_step(&params, &parts).unwrap();
        let d = exec.spec().dim;
        let region = exec.unit_region();
        assert_eq!(region, exec.param_sizes()[0]);
        assert_eq!(exec.unit_alignment(), d);
        for units in [1usize, 3, 7] {
            // Row cuts scaled to elements: even row split times d.
            let row_cuts =
                crate::sharding::ShardLayout::even(region / d, units);
            let cuts: Vec<usize> =
                row_cuts.bounds.iter().map(|&b| b * d).collect();
            let mut table_g: Vec<Vec<f32>> =
                vec![Vec::new(); parts.len()];
            let mut bias_g: Vec<Vec<f32>> =
                vec![vec![0f32; d]; parts.len()];
            let mut loss = 0f64;
            for c in cuts.windows(2) {
                let unit = c[0]..c[1];
                let out = exec
                    .run_unit_step(
                        unit.clone(),
                        &params[0][unit],
                        &params[1],
                        &parts,
                    )
                    .unwrap();
                loss += out.loss_sum;
                for (w, ug) in out.worker_unit_grads.iter().enumerate() {
                    table_g[w].extend_from_slice(ug);
                }
                for (w, bg) in out.worker_tail_grads.iter().enumerate() {
                    for (o, x) in bias_g[w].iter_mut().zip(bg) {
                        *o += x;
                    }
                }
            }
            for w in 0..parts.len() {
                assert_eq!(
                    table_g[w],
                    whole.worker_grads[w][..region],
                    "{units} units, worker {w}: table grads diverge"
                );
                assert_eq!(
                    bias_g[w],
                    whole.worker_grads[w][region..],
                    "{units} units, worker {w}: bias grads diverge"
                );
            }
            // The loss sums the same per-token terms in a different f64
            // order — equal up to rounding, not bitwise.
            assert!(
                (loss - whole.loss_sum).abs()
                    < 1e-9 * whole.loss_sum.abs().max(1.0),
                "{units} units: loss {loss} vs {}",
                whole.loss_sum
            );
        }
    }

    #[test]
    fn unit_step_rejects_misaligned_and_misshapen_units() {
        let mut exec = NativeExecutor::new(SurrogateSpec::default());
        let params = exec.init_params(2);
        let seq = exec.seq_len();
        let (tokens, targets) = sample(2, 3);
        let parts = split_batch(&tokens, &targets, seq, &[2]);
        let d = exec.spec().dim;
        // Cut not on a row boundary.
        let bad = 1..d + 1;
        assert!(exec
            .run_unit_step(bad, &params[0][1..d + 1], &params[1], &parts)
            .is_err());
        // Unit params length disagrees with the range.
        assert!(exec
            .run_unit_step(0..d, &params[0][..d - 1], &params[1], &parts)
            .is_err());
        // Past the table.
        let region = exec.unit_region();
        assert!(exec
            .run_unit_step(
                region..region + d,
                &params[1],
                &params[1],
                &parts
            )
            .is_err());
    }

    #[test]
    fn eval_loss_matches_run_step_loss() {
        let mut exec = NativeExecutor::new(SurrogateSpec::default());
        let params = exec.init_params(4);
        let seq = exec.seq_len();
        let (tokens, targets) = sample(4, 2);
        let parts = split_batch(&tokens, &targets, seq, &[4]);
        let out = exec.run_step(&params, &parts).unwrap();
        let (loss, count) =
            exec.eval_loss(&params, &tokens, &targets).unwrap();
        assert_eq!(count, out.token_count);
        assert!((loss - out.loss_sum).abs() < 1e-9 * loss.abs().max(1.0));
    }
}
