//! PJRT-backed [`StepExecutor`]: the AOT-compiled JAX grad step,
//! moved behind the executor trait from the old hard-wired trainer.
//!
//! Only this file (plus `runtime::engine`/`runtime::service`) remains
//! behind the `xla` feature — the trainer, collectives, Adam,
//! checkpointing and the elastic session all build and run without it.

use std::path::Path;
use std::sync::Arc;

use crate::runtime::{ExecHandle, ExecService, Manifest};
use crate::util::error::Result;

use super::{StepExecutor, StepOutput};

pub struct PjrtExecutor {
    service: ExecService,
    sizes: Vec<usize>,
}

impl PjrtExecutor {
    /// Load artifacts from `dir` and compile the grad-step and loss
    /// entry points.
    pub fn start(dir: &Path) -> Result<PjrtExecutor> {
        let service = ExecService::start(dir, &["grad_step", "loss"])?;
        let sizes = service.manifest().param_sizes();
        Ok(PjrtExecutor { service, sizes })
    }

    pub fn manifest(&self) -> &Manifest {
        self.service.manifest()
    }

    pub fn platform(&self) -> &str {
        self.service.platform()
    }
}

impl StepExecutor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn param_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn vocab(&self) -> usize {
        self.service.manifest().model.vocab
    }

    fn seq_len(&self) -> usize {
        self.service.manifest().model.seq_len
    }

    fn init_params(&self, seed: u64) -> Vec<Vec<f32>> {
        crate::trainer::init_params(self.service.manifest(), seed)
    }

    fn run_step(
        &mut self,
        params: &[Vec<f32>],
        parts: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<StepOutput> {
        let manifest = self.service.manifest().clone();
        let seq = manifest.model.seq_len;
        let flat_len: usize = self.sizes.iter().sum();
        // Upload the step's parameters to the device once; workers then
        // run microbatches against the device-resident copy.
        let handle = self.service.handle();
        handle.set_params(Arc::new(params.to_vec()))?;
        // Worker threads: microbatch loops with local accumulation,
        // funneling through the exec service's device queue.
        let sp = crate::telemetry::span(
            crate::telemetry::CAT_COMPUTE,
            "pjrt step",
        );
        let results: Vec<Result<(Vec<f32>, f64, f64)>> =
            std::thread::scope(|scope| {
                let mut joins = Vec::new();
                for (tokens, targets) in parts {
                    let handle = handle.clone();
                    let manifest = manifest.clone();
                    let sizes = self.sizes.clone();
                    let batch = tokens.len() / seq;
                    joins.push(scope.spawn(move || {
                        worker_grad_pass(
                            &handle, &manifest, &sizes, tokens, targets,
                            batch, flat_len,
                        )
                    }));
                }
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
        drop(sp);
        let mut worker_grads = Vec::with_capacity(parts.len());
        let mut loss_sum = 0f64;
        let mut token_count = 0f64;
        for r in results {
            let (g, ls, cnt) = r?;
            worker_grads.push(g);
            loss_sum += ls;
            token_count += cnt;
        }
        Ok(StepOutput { worker_grads, loss_sum, token_count })
    }

    fn eval_rows(&self) -> usize {
        *self.service.manifest().microbatches.iter().max().unwrap_or(&1)
    }

    fn eval_loss(
        &mut self,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, f64)> {
        let seq = self.service.manifest().model.seq_len;
        let rows = tokens.len() / seq;
        let handle = self.service.handle();
        handle.set_params(Arc::new(params.to_vec()))?;
        let (ls, cnt) =
            handle.loss(tokens.to_vec(), targets.to_vec(), rows)?;
        Ok((ls as f64, cnt as f64))
    }
}

/// One worker's full pass: decompose the batch into available
/// microbatch sizes, run grad steps, sum gradients into a flat vector.
#[allow(clippy::too_many_arguments)]
fn worker_grad_pass(
    handle: &ExecHandle,
    manifest: &Manifest,
    sizes: &[usize],
    tokens: &[i32],
    targets: &[i32],
    batch: usize,
    flat_len: usize,
) -> Result<(Vec<f32>, f64, f64)> {
    let seq = manifest.model.seq_len;
    let mut flat_grad = vec![0f32; flat_len];
    let mut loss_sum = 0f64;
    let mut token_count = 0f64;
    let mut row = 0usize;
    for m in manifest.decompose_batch(batch) {
        let lo = row * seq;
        let hi = (row + m) * seq;
        let out = handle.grad_step(
            tokens[lo..hi].to_vec(),
            targets[lo..hi].to_vec(),
            m,
        )?;
        // Accumulate (sum-loss gradients add exactly).
        let mut off = 0usize;
        for (g, &sz) in out.grads.iter().zip(sizes) {
            debug_assert_eq!(g.len(), sz);
            for (acc, v) in flat_grad[off..off + sz].iter_mut().zip(g) {
                *acc += v;
            }
            off += sz;
        }
        loss_sum += out.loss_sum as f64;
        token_count += out.token_count as f64;
        row += m;
    }
    debug_assert_eq!(row, batch);
    Ok((flat_grad, loss_sum, token_count))
}
