//! The exec subsystem: ONE interface over every training-step backend.
//!
//! PR 1 put every *planner* behind `plan::Planner`; this module does the
//! same for *executors*. Cephalo's training step is a fixed numeric
//! pipeline — uneven batch split → per-worker gradient accumulation →
//! uneven ReduceScatter over the `r_i` shard layout → sharded Adam →
//! uneven AllGather — and the only backend-specific piece is "given the
//! parameters and each worker's batch share, produce each worker's
//! summed gradients". [`StepExecutor`] captures exactly that seam, so
//! the trainer, the elastic [`crate::coordinator::session::Session`]
//! and the CLI are generic over the execution substrate (the
//! Zorse/HexiScale decoupling — see PAPERS.md):
//!
//! * [`NativeExecutor`] — dependency-free, always compiled: real f32
//!   gradients of a small built-in quadratic surrogate model, with
//!   per-step durations takeable from the `SyntheticOracle` via
//!   [`StepTimeModel`]. This is what lets the default (no-`xla`) build
//!   run live end-to-end elastic training.
//! * [`PjrtExecutor`] (`xla` feature) — the AOT-compiled JAX grad step
//!   through PJRT, moved behind the trait from the old hard-wired
//!   trainer; only this backend stays feature-gated.

pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use native::{NativeExecutor, StepTimeModel, SurrogateSpec};
#[cfg(feature = "xla")]
pub use pjrt::PjrtExecutor;

use crate::util::error::{anyhow, Result};

/// One training step's raw outcome, before the collective pipeline.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// One FULL-flat-length gradient vector per worker: the sum-loss
    /// gradients accumulated over that worker's batch share (Eq. 1's
    /// numerator; the trainer applies the 1/tokens scale after the
    /// ReduceScatter).
    pub worker_grads: Vec<Vec<f32>>,
    /// Sum of per-token losses across all workers.
    pub loss_sum: f64,
    /// Total tokens contributing to `loss_sum` (the Eq.-1 denominator).
    pub token_count: f64,
}

/// One FSDP unit's slice of a step, for unit-pipelined execution (the
/// ZeRO overlap discipline): gradients for the materialized unit plus
/// each worker's PARTIAL gradient for the resident tail. Because tail
/// contributions are dyadic-quantized, summing the partials across
/// units is bitwise the whole-step tail gradient.
#[derive(Debug, Clone)]
pub struct UnitStepOutput {
    /// One unit-length gradient vector per worker.
    pub worker_unit_grads: Vec<Vec<f32>>,
    /// One tail-length partial gradient per worker, from this unit's
    /// tokens only.
    pub worker_tail_grads: Vec<Vec<f32>>,
    /// f64 loss over the tokens this unit owns. Units partition the
    /// tokens, so the per-unit losses sum to the step loss — but in a
    /// different f64 order than [`StepOutput::loss_sum`], so the sums
    /// may differ in the last bits (parameters never do).
    pub loss_sum: f64,
}

/// A training-step backend: everything the generic trainer needs to run
/// the Cephalo numeric pipeline against some execution substrate.
///
/// Implementations must be `Send` so a trainer can migrate across
/// threads (the elastic session, benches).
pub trait StepExecutor: Send {
    /// Short backend name ("native", "pjrt") for logs and CLI output.
    fn name(&self) -> &'static str;

    /// Element count per parameter tensor, in ABI order — drives
    /// flatten/unflatten, shard layouts and checkpoints.
    fn param_sizes(&self) -> &[usize];

    /// Vocabulary the training corpus must sample from.
    fn vocab(&self) -> usize;

    /// Sequence length of one sample row.
    fn seq_len(&self) -> usize;

    /// Deterministic parameter init (same seed -> bitwise-same params).
    fn init_params(&self, seed: u64) -> Vec<Vec<f32>>;

    /// Run one step: `parts[i]` is worker i's `(tokens, targets)` batch
    /// share (row count implied by `len / seq_len`, possibly zero).
    /// Returns per-worker full-length flat gradients.
    fn run_step(
        &mut self,
        params: &[Vec<f32>],
        parts: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<StepOutput>;

    /// Timing hook: the per-step duration to report, given the
    /// per-worker batch shares and the measured wall time. Real
    /// backends return the wall time; simulation-backed ones substitute
    /// modeled durations (see [`StepTimeModel`]).
    fn step_seconds(&self, batches: &[usize], measured_wall: f64) -> f64 {
        let _ = batches;
        measured_wall
    }

    /// Preferred rows per evaluation batch (backends with compiled
    /// batch variants constrain this).
    fn eval_rows(&self) -> usize {
        8
    }

    /// `(loss_sum, token_count)` over one batch at `params`, no update.
    fn eval_loss(
        &mut self,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, f64)>;

    /// Total flat parameter length.
    fn flat_len(&self) -> usize {
        self.param_sizes().iter().sum()
    }

    /// Length of the flat-vector PREFIX that can be cut into FSDP
    /// units (0 = unit-pipelined execution unsupported; callers fall
    /// back to whole-model gather). For the native surrogate this is
    /// the `vocab x dim` embedding table; the remainder (the bias) is
    /// the resident tail, materialized whole for the step.
    fn unit_region(&self) -> usize {
        0
    }

    /// Unit cuts must land on multiples of this (the embedding row
    /// width for the native backend), so each token's parameters live
    /// in exactly one unit.
    fn unit_alignment(&self) -> usize {
        1
    }

    /// Run ONE unit's slice of the step: `unit_params` is the
    /// materialized `unit` range of the flat vector, `tail` the
    /// materialized suffix past [`Self::unit_region`]. Executing every
    /// unit and summing the tail partials reproduces [`Self::run_step`]
    /// bitwise (gradients; loss up to f64 ordering).
    fn run_unit_step(
        &mut self,
        unit: std::ops::Range<usize>,
        unit_params: &[f32],
        tail: &[f32],
        parts: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<UnitStepOutput> {
        let _ = (unit, unit_params, tail, parts);
        Err(anyhow!(
            "backend '{}' does not support unit-pipelined execution",
            self.name()
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trait_is_object_safe_and_boxable() {
        let exec: Box<dyn StepExecutor> =
            Box::new(NativeExecutor::new(SurrogateSpec::default()));
        assert_eq!(exec.name(), "native");
        assert_eq!(exec.flat_len(), exec.param_sizes().iter().sum());
        // The default timing hook passes wall time through.
        assert_eq!(exec.step_seconds(&[4, 4], 1.25), 1.25);
    }
}
