//! The exec subsystem: ONE interface over every training-step backend.
//!
//! PR 1 put every *planner* behind `plan::Planner`; this module does the
//! same for *executors*. Cephalo's training step is a fixed numeric
//! pipeline — uneven batch split → per-worker gradient accumulation →
//! uneven ReduceScatter over the `r_i` shard layout → sharded Adam →
//! uneven AllGather — and the only backend-specific piece is "given the
//! parameters and each worker's batch share, produce each worker's
//! summed gradients". [`StepExecutor`] captures exactly that seam, so
//! the trainer, the elastic [`crate::coordinator::session::Session`]
//! and the CLI are generic over the execution substrate (the
//! Zorse/HexiScale decoupling — see PAPERS.md):
//!
//! * [`NativeExecutor`] — dependency-free, always compiled: real f32
//!   gradients of a small built-in quadratic surrogate model, with
//!   per-step durations takeable from the `SyntheticOracle` via
//!   [`StepTimeModel`]. This is what lets the default (no-`xla`) build
//!   run live end-to-end elastic training.
//! * [`PjrtExecutor`] (`xla` feature) — the AOT-compiled JAX grad step
//!   through PJRT, moved behind the trait from the old hard-wired
//!   trainer; only this backend stays feature-gated.

pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use native::{NativeExecutor, StepTimeModel, SurrogateSpec};
#[cfg(feature = "xla")]
pub use pjrt::PjrtExecutor;

use crate::util::error::Result;

/// One training step's raw outcome, before the collective pipeline.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// One FULL-flat-length gradient vector per worker: the sum-loss
    /// gradients accumulated over that worker's batch share (Eq. 1's
    /// numerator; the trainer applies the 1/tokens scale after the
    /// ReduceScatter).
    pub worker_grads: Vec<Vec<f32>>,
    /// Sum of per-token losses across all workers.
    pub loss_sum: f64,
    /// Total tokens contributing to `loss_sum` (the Eq.-1 denominator).
    pub token_count: f64,
}

/// A training-step backend: everything the generic trainer needs to run
/// the Cephalo numeric pipeline against some execution substrate.
///
/// Implementations must be `Send` so a trainer can migrate across
/// threads (the elastic session, benches).
pub trait StepExecutor: Send {
    /// Short backend name ("native", "pjrt") for logs and CLI output.
    fn name(&self) -> &'static str;

    /// Element count per parameter tensor, in ABI order — drives
    /// flatten/unflatten, shard layouts and checkpoints.
    fn param_sizes(&self) -> &[usize];

    /// Vocabulary the training corpus must sample from.
    fn vocab(&self) -> usize;

    /// Sequence length of one sample row.
    fn seq_len(&self) -> usize;

    /// Deterministic parameter init (same seed -> bitwise-same params).
    fn init_params(&self, seed: u64) -> Vec<Vec<f32>>;

    /// Run one step: `parts[i]` is worker i's `(tokens, targets)` batch
    /// share (row count implied by `len / seq_len`, possibly zero).
    /// Returns per-worker full-length flat gradients.
    fn run_step(
        &mut self,
        params: &[Vec<f32>],
        parts: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<StepOutput>;

    /// Timing hook: the per-step duration to report, given the
    /// per-worker batch shares and the measured wall time. Real
    /// backends return the wall time; simulation-backed ones substitute
    /// modeled durations (see [`StepTimeModel`]).
    fn step_seconds(&self, batches: &[usize], measured_wall: f64) -> f64 {
        let _ = batches;
        measured_wall
    }

    /// Preferred rows per evaluation batch (backends with compiled
    /// batch variants constrain this).
    fn eval_rows(&self) -> usize {
        8
    }

    /// `(loss_sum, token_count)` over one batch at `params`, no update.
    fn eval_loss(
        &mut self,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, f64)>;

    /// Total flat parameter length.
    fn flat_len(&self) -> usize {
        self.param_sizes().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trait_is_object_safe_and_boxable() {
        let exec: Box<dyn StepExecutor> =
            Box::new(NativeExecutor::new(SurrogateSpec::default()));
        assert_eq!(exec.name(), "native");
        assert_eq!(exec.flat_len(), exec.param_sizes().iter().sum());
        // The default timing hook passes wall time through.
        assert_eq!(exec.step_seconds(&[4, 4], 1.25), 1.25);
    }
}
