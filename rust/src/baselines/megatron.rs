//! Megatron-Het (§4.1): Megatron-LM adapted for heterogeneous clusters.
//!
//! Topology: one pipeline stage per node; within a node, GPUs form a
//! (data-parallel x tensor-parallel) grid, with ZeRO-2 sharding of
//! gradients + optimizer state inside the DP group (§4.3). Layers are
//! partitioned across stages proportionally to node compute — but every
//! pipeline must be partitioned *identically*, so mixed GPU types within
//! a node put slow GPUs on the same stage as fast ones and the slowest
//! bounds the stage (§4.2's P40 bottleneck).
//!
//! Tensor parallelism is only available for architectures Megatron-LM
//! implements (GPT and BERT); ViT / Llama variants run tp = 1, which is
//! why the big ViT-e and Llama-3B rows OOM in Table 4.

use std::time::Instant;

use super::{allreduce_time, pow2_candidates, PlanContext,
            PlanDiagnostics, PlanOutcome, Planner};
use crate::cluster::gbps_to_bytes_per_sec;
use crate::memory::usable_capacity;
use crate::optimizer::PlanError;
use crate::sim::{simulate_pipeline, PipelineWorkload, StageSpec};

pub struct MegatronHet;

/// Does Megatron-LM support tensor parallelism for this model family?
fn tp_supported(model_name: &str) -> bool {
    let n = model_name.to_ascii_lowercase();
    n.contains("gpt") || n.contains("bert")
}

impl Planner for MegatronHet {
    fn name(&self) -> &'static str {
        "Megatron-Het"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        self.plan_inner(ctx).map_err(|e| e.tagged(self.name()))
    }
}

impl MegatronHet {
    fn plan_inner(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let nodes = &ctx.cluster.nodes;
        let stages = nodes.len();
        let model = ctx.model;

        // Compute-proportional layer partition (identical pipelines).
        let node_tflops: Vec<f64> = nodes
            .iter()
            .map(|n| n.gpus.iter().map(|g| g.tflops_fp32).sum())
            .collect();
        let layer_split = crate::optimizer::ablations::proportional_split(
            model.layers,
            &node_tflops,
        );

        // GPU flat index of each node's slots.
        let gpus = ctx.cluster.gpus();
        let mut node_slots: Vec<Vec<usize>> = vec![Vec::new(); stages];
        for (i, g) in gpus.iter().enumerate() {
            node_slots[g.node].push(i);
        }

        let gpus_per_node = nodes
            .iter()
            .map(|n| n.gpus.len())
            .min()
            .unwrap_or(0);
        if gpus_per_node == 0 {
            return Err(PlanError::Infeasible("empty node".into()));
        }

        let tp_options: Vec<usize> = if tp_supported(&model.name) {
            (0..)
                .map(|e| 1usize << e)
                .take_while(|t| *t <= gpus_per_node)
                .collect()
        } else {
            vec![1]
        };

        let mut best: Option<(f64, String)> = None;
        let mut oom: Option<PlanError> = None;
        let mut candidates = 0u64;

        for &tp in &tp_options {
            if gpus_per_node % tp != 0 {
                continue;
            }
            let dp = gpus_per_node / tp; // pipelines
            if ctx.batch % dp != 0 {
                continue;
            }
            let per_pipeline = ctx.batch / dp;
            for &m in &pow2_candidates(per_pipeline) {
                if per_pipeline % m != 0 {
                    continue;
                }
                let l = per_pipeline / m;
                candidates += 1;
                match self.evaluate(ctx, &layer_split, &node_slots, tp, dp,
                                    m, l) {
                    Ok(latency) => {
                        let cfg = format!(
                            "pp={stages} tp={tp} dp={dp} micro={m} x {l}"
                        );
                        if best
                            .as_ref()
                            .map(|(b, _)| latency < *b)
                            .unwrap_or(true)
                        {
                            best = Some((latency, cfg));
                        }
                    }
                    Err(e @ PlanError::OutOfMemory { .. }) => {
                        oom.get_or_insert(e);
                    }
                    Err(_) => {}
                }
            }
        }

        match best {
            Some((latency, config)) => Ok(PlanOutcome {
                planner: self.name().into(),
                iter_latency: latency,
                throughput: ctx.batch as f64 / latency,
                config,
                // Pipeline stages don't map onto the FSDP division.
                assignment: None,
                diagnostics: PlanDiagnostics {
                    solve_seconds: t0.elapsed().as_secs_f64(),
                    candidates,
                    ..Default::default()
                },
            }),
            None => Err(oom.unwrap_or(PlanError::Infeasible(
                "no megatron configuration feasible".into(),
            ))),
        }
    }

    /// Memory-check one configuration and simulate the slowest pipeline.
    #[allow(clippy::too_many_arguments)]
    fn evaluate(
        &self,
        ctx: &PlanContext<'_>,
        layer_split: &[usize],
        node_slots: &[Vec<usize>],
        tp: usize,
        dp: usize,
        m: usize,
        l: usize,
    ) -> Result<f64, PlanError> {
        let model = ctx.model;
        let stages = layer_split.len();
        let unit_params = model.params_per_layer() as f64;

        // ---- memory check (per GPU, worst in each stage) ----
        for (s, slots) in node_slots.iter().enumerate() {
            let stage_params = layer_split[s] as f64 * unit_params / tp as f64;
            // ZeRO-2: params replicated in DP, grads+opt state sharded.
            let state = 4.0 * stage_params
                + 12.0 * stage_params / dp as f64;
            // In-flight activations: the GPipe all-forward wave holds
            // boundary checkpoints for ALL l microbatches of the stage.
            let acts = model.boundary_activation_bytes()
                * (m * l * layer_split[s]) as f64
                / tp as f64;
            for &slot in slots {
                let prof = &ctx.profile.per_gpu[slot];
                let workspace =
                    prof.mem.intercept + prof.mem.slope * m as f64 / tp as f64;
                let need = state + acts + workspace;
                let cap = usable_capacity(prof.capacity);
                if need > cap {
                    return Err(PlanError::oom_in(
                        slot,
                        need,
                        cap,
                        format!("pp={stages} tp={tp} dp={dp} \
                                 micro={m} x {l}"),
                    ));
                }
            }
        }

        // ---- latency: simulate the SLOWEST pipeline (its finish gates
        // the gradient sync; identical partitions mean the pipeline
        // containing each node's slowest GPU is the straggler) ----
        let mut stage_specs = Vec::with_capacity(stages);
        for (s, slots) in node_slots.iter().enumerate() {
            // Slowest GPU of the node runs this stage in some pipeline.
            let worst = slots
                .iter()
                .map(|&i| {
                    (ctx.oracle.fwd_latency(i, m),
                     ctx.oracle.bwd_latency(i, m))
                })
                .max_by(|a, b| (a.0 + a.1).partial_cmp(&(b.0 + b.1)).unwrap())
                .unwrap();
            // tp divides compute; adds two allreduces per layer per
            // microbatch (fwd) + two (bwd) over the intra-node link.
            let tp_comm = if tp > 1 {
                let bytes =
                    (m * model.seq_len * model.d_model * 4) as f64;
                let node = &ctx.cluster.nodes[s];
                4.0 * allreduce_time(bytes, tp, node.intra_bw_gbps)
                    * layer_split[s] as f64
            } else {
                0.0
            };
            stage_specs.push(StageSpec {
                device: s,
                fwd_micro: worst.0 * layer_split[s] as f64 / tp as f64
                    + tp_comm / 3.0,
                bwd_micro: worst.1 * layer_split[s] as f64 / tp as f64
                    + tp_comm * 2.0 / 3.0,
            });
        }
        let p2p_bytes = (m * model.seq_len * model.d_model * 4) as f64;
        let p2p = 10e-6
            + p2p_bytes
                / gbps_to_bytes_per_sec(ctx.cluster.inter_bw_gbps);
        let (pipe_latency, _) = simulate_pipeline(&PipelineWorkload {
            stages: stage_specs,
            microbatches: l,
            p2p_time: p2p,
        });

        // Gradient allreduce across the dp pipelines per stage (ZeRO-2
        // reduce-scatter + allgather of fp32 grads), overlapping stages.
        let grad_sync = node_slots
            .iter()
            .enumerate()
            .map(|(s, _)| {
                let bytes = layer_split[s] as f64 * unit_params * 4.0
                    / tp as f64;
                allreduce_time(
                    bytes,
                    dp,
                    ctx.cluster.nodes[s].intra_bw_gbps,
                )
            })
            .fold(0.0, f64::max);
        Ok(pipe_latency + grad_sync)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::Ctx;
    use crate::cluster::Cluster;

    #[test]
    fn trains_small_models_on_cluster_a() {
        let c = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        let out = MegatronHet.plan(&c.ctx(128)).expect("feasible");
        assert!(out.throughput > 0.0);
        assert!(out.config.contains("pp=2"));
    }

    #[test]
    fn table4_oom_pattern() {
        // Paper Table 4: Megatron-Het OOMs on ViT-e and Llama 3B
        // (no Megatron tensor parallelism for those architectures).
        for model in ["ViT-e", "Llama 3B"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            let r = MegatronHet.plan(&c.ctx(128));
            assert!(r.is_err(), "{model} should OOM, got {r:?}");
        }
        // ...but trains ViT-G, GPT 2.7B, Tiny Llama.
        for model in ["ViT-G", "GPT 2.7B", "Tiny Llama"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            let r = MegatronHet.plan(&c.ctx(128));
            assert!(r.is_ok(), "{model} should train: {:?}", r.err());
        }
    }

    #[test]
    fn tp_support_matrix() {
        assert!(tp_supported("GPT 2.7B"));
        assert!(tp_supported("BERT-Large"));
        assert!(!tp_supported("ViT-e"));
        assert!(!tp_supported("Llama 3B"));
    }

    #[test]
    fn slower_than_ideal_due_to_p40_bottleneck() {
        // §4.2: the P40s bound both stages; Megatron cannot reach the
        // cluster's aggregate compute.
        let c = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        let out = MegatronHet.plan(&c.ctx(128)).unwrap();
        // Aggregate-compute ideal iteration time.
        let total_flops = c.model.iter_flops(128, true);
        let ideal = total_flops
            / (c.cluster.total_tflops() * 1e12 * 0.42);
        assert!(
            out.iter_latency > 1.5 * ideal,
            "megatron {} vs ideal {ideal}",
            out.iter_latency
        );
    }

    #[test]
    fn works_on_cluster_b() {
        let c = Ctx::new(Cluster::cluster_b(), "GPT 6.7B");
        let out = MegatronHet.plan(&c.ctx(512)).expect("feasible");
        assert!(out.throughput > 0.0);
    }
}
