//! FlashFlex (Yan et al., 2024): heterogeneous pipelines with ZeRO-2
//! sharding and asymmetric stage sizes.
//!
//! Faithful structural model (§4.2/§4.3):
//! * GPUs are grouped by type into pipeline stages (a stage may have a
//!   different GPU count than its neighbours — FlashFlex's flexibility).
//! * Layers are partitioned across stages proportionally to stage
//!   *memory* (the paper's criticism: this assigns T4 stages V100-sized
//!   compute, so slow stages bottleneck the pipeline).
//! * ZeRO-2 within each stage group (params replicated, grads +
//!   optimizer state sharded).
//! * Microbatch size / accumulation manually swept (powers of two), the
//!   best reported.

use std::time::Instant;

use super::{allreduce_time, pow2_candidates, PlanContext,
            PlanDiagnostics, PlanOutcome, Planner};
use crate::cluster::gbps_to_bytes_per_sec;
use crate::memory::usable_capacity;
use crate::optimizer::PlanError;
use crate::sim::{simulate_pipeline, PipelineWorkload, StageSpec};

pub struct FlashFlex;

/// One stage: the flat GPU slots of a single GPU type.
struct StageGroup {
    slots: Vec<usize>,
    mem_bytes: f64,
}

fn group_by_type(ctx: &PlanContext<'_>) -> Vec<StageGroup> {
    let gpus = ctx.cluster.gpus();
    let mut order: Vec<String> = Vec::new();
    for g in &gpus {
        if !order.contains(&g.spec.name) {
            order.push(g.spec.name.clone());
        }
    }
    order
        .iter()
        .map(|name| {
            let slots: Vec<usize> = gpus
                .iter()
                .enumerate()
                .filter(|(_, g)| &g.spec.name == name)
                .map(|(i, _)| i)
                .collect();
            let mem = slots
                .iter()
                .map(|&i| gpus[i].spec.mem_bytes())
                .sum();
            StageGroup { slots, mem_bytes: mem }
        })
        .collect()
}

impl Planner for FlashFlex {
    fn name(&self) -> &'static str {
        "FlashFlex"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        self.plan_inner(ctx).map_err(|e| e.tagged(self.name()))
    }
}

impl FlashFlex {
    fn plan_inner(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let model = ctx.model;
        let groups = group_by_type(ctx);
        let stages = groups.len();
        if stages == 0 {
            return Err(PlanError::Infeasible("empty cluster".into()));
        }

        // Memory-proportional layer partition.
        let mems: Vec<f64> = groups.iter().map(|g| g.mem_bytes).collect();
        let mut layer_split = crate::optimizer::ablations::proportional_split(
            model.layers,
            &mems,
        );
        // Every stage needs >= 1 layer; steal from the largest.
        for i in 0..layer_split.len() {
            while layer_split[i] == 0 {
                let max = (0..layer_split.len())
                    .max_by_key(|&j| layer_split[j])
                    .unwrap();
                if layer_split[max] <= 1 {
                    return Err(PlanError::Infeasible(
                        "more stages than layers".into(),
                    ));
                }
                layer_split[max] -= 1;
                layer_split[i] += 1;
            }
        }

        let unit_params = model.params_per_layer() as f64;
        let mut best: Option<(f64, String)> = None;
        let mut oom: Option<PlanError> = None;
        let mut candidates = 0u64;

        // FlashFlex supports per-stage tensor parallelism (less than
        // Megatron, §4.3); searched alongside the microbatch size.
        for tp in [1usize, 2, 4] {
            if groups.iter().any(|g| g.slots.len() % tp != 0) {
                continue;
            }
        for &m in &pow2_candidates(ctx.batch) {
            if ctx.batch % m != 0 {
                continue;
            }
            let l = ctx.batch / m;
            candidates += 1;
            match self.evaluate(ctx, &groups, &layer_split, unit_params, m,
                                l, tp)
            {
                Ok(latency) => {
                    let cfg = format!(
                        "stages={stages} layers={layer_split:?} tp={tp} \
                         micro={m} x {l}"
                    );
                    if best.as_ref().map(|(b, _)| latency < *b).unwrap_or(true)
                    {
                        best = Some((latency, cfg));
                    }
                }
                Err(e @ PlanError::OutOfMemory { .. }) => {
                    oom.get_or_insert(e);
                }
                Err(_) => {}
            }
        }
        }
        match best {
            Some((latency, config)) => Ok(PlanOutcome {
                planner: self.name().into(),
                iter_latency: latency,
                throughput: ctx.batch as f64 / latency,
                config,
                // Heterogeneous pipeline stages, no FSDP division.
                assignment: None,
                diagnostics: PlanDiagnostics {
                    solve_seconds: t0.elapsed().as_secs_f64(),
                    candidates,
                    ..Default::default()
                },
            }),
            None => Err(oom.unwrap_or(PlanError::Infeasible(
                "no flashflex configuration feasible".into(),
            ))),
        }
    }

    fn evaluate(
        &self,
        ctx: &PlanContext<'_>,
        groups: &[StageGroup],
        layer_split: &[usize],
        unit_params: f64,
        m: usize,
        l: usize,
        tp: usize,
    ) -> Result<f64, PlanError> {
        let model = ctx.model;

        // Memory per GPU in each stage (ZeRO-2 within the group).
        for (s, group) in groups.iter().enumerate() {
            let k = (group.slots.len() / tp) as f64;
            let stage_params =
                layer_split[s] as f64 * unit_params / tp as f64;
            let state = 4.0 * stage_params + 12.0 * stage_params / k;
            // Each stage GPU handles a 1/k slice of each microbatch;
            // the GPipe all-forward wave keeps all l microbatches'
            // boundary checkpoints in flight.
            let m_eff = m.div_ceil((group.slots.len() / tp).max(1));
            let acts = model.boundary_activation_bytes()
                * (m_eff * l * layer_split[s]) as f64
                / tp as f64;
            for &slot in &group.slots {
                let prof = &ctx.profile.per_gpu[slot];
                let workspace =
                    prof.mem.intercept + prof.mem.slope * m_eff as f64;
                let need = state + acts + workspace;
                let cap = usable_capacity(prof.capacity);
                if need > cap {
                    return Err(PlanError::oom_in(
                        slot,
                        need,
                        cap,
                        format!("stage={s} tp={tp} micro={m} x {l}"),
                    ));
                }
            }
        }

        // Stage compute time per microbatch: the microbatch is split
        // across the stage's GPUs (data parallel within the stage);
        // the stage's GPU type is uniform so any slot's latency works.
        let stage_specs: Vec<StageSpec> = groups
            .iter()
            .enumerate()
            .map(|(s, group)| {
                let dp = (group.slots.len() / tp).max(1);
                let m_eff = m.div_ceil(dp).max(1);
                let rep = group.slots[0];
                // tp divides per-GPU compute but adds per-layer
                // activation allreduces over the intra-node link.
                let gpus = ctx.cluster.gpus();
                let node = gpus[rep].node;
                let tp_comm = if tp > 1 {
                    let bytes =
                        (m_eff * model.seq_len * model.d_model * 4) as f64;
                    4.0 * allreduce_time(
                        bytes,
                        tp,
                        ctx.cluster.nodes[node].intra_bw_gbps,
                    ) * layer_split[s] as f64
                } else {
                    0.0
                };
                StageSpec {
                    device: s,
                    fwd_micro: ctx.oracle.fwd_latency(rep, m_eff)
                        * layer_split[s] as f64 / tp as f64
                        + tp_comm / 3.0,
                    bwd_micro: ctx.oracle.bwd_latency(rep, m_eff)
                        * layer_split[s] as f64 / tp as f64
                        + tp_comm * 2.0 / 3.0,
                }
            })
            .collect();
        let p2p_bytes = (m * model.seq_len * model.d_model * 4) as f64;
        let p2p = 10e-6
            + p2p_bytes
                / gbps_to_bytes_per_sec(ctx.cluster.inter_bw_gbps);
        let (pipe_latency, _) = simulate_pipeline(&PipelineWorkload {
            stages: stage_specs,
            microbatches: l,
            p2p_time: p2p,
        });

        // ZeRO-2 gradient reduce-scatter + param allgather within each
        // stage group at iteration end.
        let grad_sync = groups
            .iter()
            .enumerate()
            .map(|(s, group)| {
                let bytes = layer_split[s] as f64 * unit_params * 4.0;
                allreduce_time(
                    bytes,
                    group.slots.len(),
                    ctx.cluster.inter_bw_gbps,
                )
            })
            .fold(0.0, f64::max);
        Ok(pipe_latency + grad_sync)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::megatron::MegatronHet;
    use crate::baselines::testutil::Ctx;
    use crate::cluster::Cluster;

    #[test]
    fn trains_everything_in_table4() {
        // Paper Table 4: FlashFlex has no OOM entries on cluster A.
        for model in ["ViT-G", "ViT-e", "BERT-Large", "GPT 2.7B",
                      "Tiny Llama", "Llama 3B"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            let r = FlashFlex.plan(&c.ctx(128));
            assert!(r.is_ok(), "{model}: {:?}", r.err());
        }
    }

    #[test]
    fn beats_megatron_on_big_models_cluster_a() {
        // Table 4 shape: FlashFlex > Megatron-Het for GPT 2.7B.
        let c = Ctx::new(Cluster::cluster_a(), "GPT 2.7B");
        let ff = FlashFlex.plan(&c.ctx(128)).unwrap();
        let mg = MegatronHet.plan(&c.ctx(128)).unwrap();
        assert!(
            ff.throughput > mg.throughput,
            "flashflex {} vs megatron {}",
            ff.throughput,
            mg.throughput
        );
    }

    #[test]
    fn memory_proportional_partition_bottlenecks_on_slow_types() {
        // Cluster B: T4s hold ~half the memory but are the slowest;
        // FlashFlex's throughput is far below the aggregate-compute
        // ideal.
        let c = Ctx::new(Cluster::cluster_b(), "ViT-e");
        let out = FlashFlex.plan(&c.ctx(512)).unwrap();
        let ideal = c.model.iter_flops(512, true)
            / (c.cluster.total_tflops() * 1e12 * 0.42);
        assert!(out.iter_latency > 1.3 * ideal);
    }

    #[test]
    fn groups_by_type() {
        let c = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        let binding = c.ctx(64);
        let groups = group_by_type(&binding);
        // L4, A6000, P40, P100.
        assert_eq!(groups.len(), 4);
        let sizes: Vec<usize> =
            groups.iter().map(|g| g.slots.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 8);
    }
}
