//! Baseline FSDP (even-everything) with the PyTorch memory profile:
//! even batch split, no gradient accumulation, even state sharding,
//! layer-boundary checkpoints resident on GPU, fragmentation from the
//! default allocator behaviour. The Table-8 / Fig.-7 "FSDP" row.

use std::time::Instant;

use super::{PlanContext, PlanDiagnostics, PlanOutcome, Planner,
            PYTORCH_FRAGMENTATION};
use crate::memory::{state_bytes, usable_capacity};
use crate::optimizer::{Assignment, GpuAssign, PlanError};

pub struct FsdpBaseline;

impl Planner for FsdpBaseline {
    fn name(&self) -> &'static str {
        "FSDP"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        self.plan_inner(ctx).map_err(|e| e.tagged(self.name()))
    }
}

impl FsdpBaseline {
    fn plan_inner(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let n = ctx.cluster.num_gpus();
        let model = ctx.model;
        if ctx.batch % n != 0 {
            return Err(PlanError::Infeasible(format!(
                "batch {} not divisible by {n} GPUs",
                ctx.batch
            )));
        }
        let b = ctx.batch / n;
        let even_state = state_bytes(model.total_params() as f64) / n as f64;

        for i in 0..n {
            let prof = &ctx.profile.per_gpu[i];
            let checkpoints = model.boundary_activation_bytes()
                * (b * model.layers) as f64;
            let compute = (prof.mem.intercept
                + prof.mem.slope * b as f64
                + checkpoints)
                * PYTORCH_FRAGMENTATION;
            let need = even_state + compute;
            let cap = usable_capacity(prof.capacity);
            if need > cap {
                return Err(PlanError::oom_in(
                    i,
                    need,
                    cap,
                    format!("even dp: b_i={b}, even shard"),
                ));
            }
        }

        // Latency via Eqs. 2/3: slowest GPU bounds each phase; even
        // sharding, so even collectives.
        let ag = ctx.profile.unit_allgather();
        let rs = ctx.profile.unit_reduce_scatter();
        let tf = (0..n)
            .map(|i| ctx.oracle.fwd_latency(i, b))
            .fold(0.0, f64::max);
        let tb = (0..n)
            .map(|i| ctx.oracle.bwd_latency(i, b))
            .fold(0.0, f64::max);
        let layer = tf.max(ag) + tb.max(ag + rs);
        let latency = layer * model.layers as f64;
        // FSDP's division DOES map onto the per-GPU assignment shape:
        // even batch, no accumulation, even state.
        let assignment = Assignment {
            per_gpu: (0..n)
                .map(|_| GpuAssign {
                    microbatch: b,
                    num_micro: 1,
                    state_ratio: 1.0 / n as f64,
                })
                .collect(),
            layer_latency: layer,
            iter_latency: latency,
        };
        Ok(PlanOutcome {
            planner: self.name().into(),
            iter_latency: latency,
            throughput: ctx.batch as f64 / latency,
            config: format!("even dp: {b}/GPU, even shard"),
            assignment: Some(assignment),
            diagnostics: PlanDiagnostics {
                solve_seconds: t0.elapsed().as_secs_f64(),
                candidates: 1,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::Ctx;
    use crate::cluster::Cluster;

    #[test]
    fn table8_fsdp_pattern() {
        // FSDP trains ViT-G/BERT-Large/BERT-XLarge/TinyLlama @ 128 but
        // OOMs GPT 2.7B and Llama 3B on cluster A (Supplementary D).
        for model in ["ViT-G", "BERT-Large", "BERT-XLarge", "Tiny Llama"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            let r = FsdpBaseline.plan(&c.ctx(128));
            assert!(r.is_ok(), "{model} @128: {:?}", r.err());
        }
        for model in ["GPT 2.7B", "Llama 3B", "ViT-e"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            assert!(
                FsdpBaseline.plan(&c.ctx(128)).is_err(),
                "{model} should OOM @128"
            );
        }
    }

    #[test]
    fn ooms_at_larger_batch() {
        // Table 8: ViT-G trains at 128, OOMs at 256.
        let c = Ctx::new(Cluster::cluster_a(), "ViT-G");
        assert!(FsdpBaseline.plan(&c.ctx(128)).is_ok());
        assert!(FsdpBaseline.plan(&c.ctx(256)).is_err());
    }

    #[test]
    fn bottlenecked_by_slowest_gpu() {
        // The even split leaves fast GPUs idle: throughput is bounded by
        // the P100's speed, not the aggregate.
        let c = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        let out = FsdpBaseline.plan(&c.ctx(128)).unwrap();
        let ideal = c.model.iter_flops(128, true)
            / (c.cluster.total_tflops() * 1e12 * 0.42);
        assert!(out.iter_latency > 1.8 * ideal);
    }
}
