//! Whale (Jia et al., 2022): heterogeneity-aware data parallelism.
//!
//! Batch sizes are assigned proportionally to profiled GPU speed, but
//! the training state is FULLY REPLICATED on every GPU (vanilla DP). As
//! Supplementary D shows, that replication OOMs everything but
//! BERT-Large on cluster A: P100s run out while P40s sit at 50%
//! utilization — the compute/memory coupling Cephalo breaks.

use std::time::Instant;

use super::{allreduce_time, PlanContext, PlanDiagnostics, PlanOutcome,
            Planner, PYTORCH_FRAGMENTATION};
use crate::memory::{state_bytes, usable_capacity};
use crate::optimizer::ablations::proportional_split;
use crate::optimizer::PlanError;

pub struct Whale;

impl Planner for Whale {
    fn name(&self) -> &'static str {
        "Whale"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        self.plan_inner(ctx).map_err(|e| e.tagged(self.name()))
    }
}

impl Whale {
    fn plan_inner(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let n = ctx.cluster.num_gpus();
        let model = ctx.model;

        // Batch ∝ profiled speed (saturated per-sample throughput).
        let speeds: Vec<f64> = (0..n)
            .map(|i| {
                let m = 8;
                m as f64
                    / (ctx.oracle.fwd_latency(i, m)
                        + ctx.oracle.bwd_latency(i, m))
            })
            .collect();
        let batches = proportional_split(ctx.batch, &speeds);

        // Memory: full replicated state + per-batch compute + layer
        // checkpoints, with PyTorch fragmentation (no Cephalo sync).
        let full_state = state_bytes(model.total_params() as f64);
        for (i, &b) in batches.iter().enumerate() {
            if b == 0 {
                continue;
            }
            let prof = &ctx.profile.per_gpu[i];
            let checkpoints = model.boundary_activation_bytes()
                * (b * model.layers) as f64;
            let compute = (prof.mem.intercept
                + prof.mem.slope * b as f64
                + checkpoints)
                * PYTORCH_FRAGMENTATION;
            let need = full_state + compute;
            let cap = usable_capacity(prof.capacity);
            if need > cap {
                return Err(PlanError::oom_in(
                    i,
                    need,
                    cap,
                    format!("replicated state, b_i={b}"),
                ));
            }
        }

        // Latency: slowest GPU's full fwd+bwd + ring allreduce of the
        // full fp32 gradient.
        let compute = batches
            .iter()
            .enumerate()
            .filter(|(_, b)| **b > 0)
            .map(|(i, &b)| {
                (ctx.oracle.fwd_latency(i, b)
                    + ctx.oracle.bwd_latency(i, b))
                    * model.layers as f64
            })
            .fold(0.0, f64::max);
        let sync = allreduce_time(
            model.total_params() as f64 * 4.0,
            n,
            ctx.cluster.ring_bw_gbps(),
        );
        let latency = compute + sync;
        Ok(PlanOutcome {
            planner: self.name().into(),
            iter_latency: latency,
            throughput: ctx.batch as f64 / latency,
            config: format!("dp batches={batches:?}"),
            // Full replication has no (sum-to-1) state-ratio encoding.
            assignment: None,
            diagnostics: PlanDiagnostics {
                solve_seconds: t0.elapsed().as_secs_f64(),
                candidates: 1,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::Ctx;
    use crate::cluster::Cluster;

    #[test]
    fn table8_only_bert_large_fits() {
        // Supplementary D: Whale trains only BERT-Large on cluster A.
        let ok = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        assert!(Whale.plan(&ok.ctx(128)).is_ok());
        for model in ["ViT-G", "BERT-XLarge", "GPT 2.7B", "Tiny Llama"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            let r = Whale.plan(&c.ctx(128));
            assert!(
                matches!(&r, Err(e) if e.is_oom()),
                "{model} should OOM: {r:?}"
            );
            // Errors are attributed and name the OOMing configuration.
            let msg = r.unwrap_err().to_string();
            assert!(msg.contains("[Whale]"), "{msg}");
            assert!(msg.contains("replicated state"), "{msg}");
        }
    }

    #[test]
    fn batches_track_speed() {
        let c = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        let out = Whale.plan(&c.ctx(128)).unwrap();
        // The A6000 (38.7 TF) should get several times the P100 share —
        // visible in the config string.
        assert!(out.config.contains("dp batches="));
        assert!(out.throughput > 0.0);
    }
}
