//! HAP (Zhang et al., 2024b): SPMD training with automated sharding —
//! tensor parallelism ACROSS nodes + data parallelism within nodes,
//! batch and parameters sharded unevenly to match compute.
//!
//! HAP does not model per-GPU memory constraints (Supplementary D), so
//! it OOMs on everything but BERT-Large on cluster A; and its cross-node
//! tensor parallelism pays per-layer activation allreduces over the slow
//! inter-node link, making it slower than even baseline FSDP.

use std::time::Instant;

use super::{allreduce_time, PlanContext, PlanDiagnostics, PlanOutcome,
            Planner};
use crate::memory::usable_capacity;
use crate::optimizer::ablations::proportional_split;
use crate::optimizer::PlanError;

pub struct Hap;

impl Planner for Hap {
    fn name(&self) -> &'static str {
        "HAP"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        self.plan_inner(ctx).map_err(|e| e.tagged(self.name()))
    }
}

impl Hap {
    fn plan_inner(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let model = ctx.model;
        let nodes = &ctx.cluster.nodes;
        let tp = nodes.len(); // tensor parallel across nodes
        if tp < 1 {
            return Err(PlanError::Infeasible("empty cluster".into()));
        }
        let dp = nodes.iter().map(|n| n.gpus.len()).min().unwrap();

        // Uneven parameter shard per node ∝ node compute (HAP's
        // automated sharding); uneven batch within DP ∝ GPU compute.
        let node_tflops: Vec<f64> = nodes
            .iter()
            .map(|n| n.gpus.iter().map(|g| g.tflops_fp32).sum())
            .collect();
        let total_tflops: f64 = node_tflops.iter().sum();

        let gpus = ctx.cluster.gpus();
        // DP replica r uses GPU r of each node; batch ∝ replica speed.
        let replica_speed: Vec<f64> = (0..dp)
            .map(|r| {
                (0..tp)
                    .map(|s| {
                        let slot = ctx
                            .cluster
                            .gpus()
                            .iter()
                            .enumerate()
                            .filter(|(_, g)| g.node == s)
                            .map(|(i, _)| i)
                            .nth(r)
                            .unwrap();
                        let m = 8;
                        m as f64
                            / (ctx.oracle.fwd_latency(slot, m)
                                + ctx.oracle.bwd_latency(slot, m))
                    })
                    .fold(f64::INFINITY, f64::min) // replica bound by slowest shard
            })
            .collect();
        let batches = proportional_split(ctx.batch, &replica_speed);

        // ---- memory (HAP ignores it; we detect the resulting OOM) ----
        let total_params = model.total_params() as f64;
        for (i, g) in gpus.iter().enumerate() {
            let node_share = node_tflops[g.node] / total_tflops;
            // Parameters sharded by TP (node share), replicated in DP;
            // full fp32 Adam state for the shard.
            let state = 16.0 * total_params * node_share;
            let r = g.index_in_node.min(dp - 1);
            let b = batches[r].max(1);
            let prof = &ctx.profile.per_gpu[i];
            let checkpoints = model.boundary_activation_bytes()
                * (b * model.layers) as f64;
            let need =
                state + prof.mem.intercept + prof.mem.slope * b as f64
                    + checkpoints;
            let cap = usable_capacity(prof.capacity);
            if need > cap {
                return Err(PlanError::oom_in(
                    i,
                    need,
                    cap,
                    format!("tp={tp} dp={dp} b_i={b}"),
                ));
            }
        }

        // ---- latency ----
        // Compute: slowest replica's model pass with its TP speedup
        // (bounded by its slowest shard GPU).
        let compute = (0..dp)
            .map(|r| {
                let b = batches[r];
                if b == 0 {
                    return 0.0;
                }
                (0..tp)
                    .map(|s| {
                        let slot = gpus
                            .iter()
                            .enumerate()
                            .filter(|(_, g)| g.node == s)
                            .map(|(i, _)| i)
                            .nth(r)
                            .unwrap();
                        let share = node_tflops[s] / total_tflops;
                        (ctx.oracle.fwd_latency(slot, b)
                            + ctx.oracle.bwd_latency(slot, b))
                            * model.layers as f64
                            * share
                    })
                    .fold(0.0, f64::max)
            })
            .fold(0.0, f64::max);
        // TP activation allreduces: 4 per layer per replica-batch over
        // the INTER-NODE link — HAP's killer overhead.
        let max_b = *batches.iter().max().unwrap();
        let act_bytes =
            (max_b * model.seq_len * model.d_model * 4) as f64;
        let tp_comm = 4.0
            * model.layers as f64
            * allreduce_time(act_bytes, tp, ctx.cluster.inter_bw_gbps);
        // DP gradient allreduce within nodes.
        let grad_sync = allreduce_time(
            total_params * 4.0 / tp as f64,
            dp,
            nodes.iter().map(|n| n.intra_bw_gbps).fold(f64::INFINITY,
                                                       f64::min),
        );
        let latency = compute + tp_comm + grad_sync;
        Ok(PlanOutcome {
            planner: self.name().into(),
            iter_latency: latency,
            throughput: ctx.batch as f64 / latency,
            config: format!("tp={tp} dp={dp} batches={batches:?}"),
            // Cross-node TP sharding is not an FSDP-style division.
            assignment: None,
            diagnostics: PlanDiagnostics {
                solve_seconds: t0.elapsed().as_secs_f64(),
                candidates: 1,
                ..Default::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::Ctx;
    use crate::cluster::Cluster;
    use crate::optimizer::ablations::fsdp_even;

    #[test]
    fn table8_only_bert_large_fits() {
        let ok = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        assert!(Hap.plan(&ok.ctx(128)).is_ok());
        for model in ["ViT-G", "BERT-XLarge", "GPT 2.7B"] {
            let c = Ctx::new(Cluster::cluster_a(), model);
            let r = Hap.plan(&c.ctx(128));
            assert!(
                matches!(&r, Err(e) if e.is_oom()),
                "{model} should OOM: {r:?}"
            );
            let msg = r.unwrap_err().to_string();
            assert!(msg.contains("[HAP]") && msg.contains("tp="), "{msg}");
        }
    }

    #[test]
    fn slower_than_fsdp_due_to_cross_node_tp() {
        // Table 8: HAP 17.48 vs FSDP 24.50 on BERT-Large @ 128.
        let c = Ctx::new(Cluster::cluster_a(), "BERT-Large");
        let hap = Hap.plan(&c.ctx(128)).unwrap();
        let fsdp = fsdp_even(&c.profile, 128).unwrap();
        let fsdp_tput = 128.0 / fsdp.iter_latency;
        assert!(
            hap.throughput < fsdp_tput,
            "HAP {} should trail FSDP {fsdp_tput}",
            hap.throughput
        );
    }
}
