//! Baseline heterogeneous-training systems (§4.1, Supplementary D).
//!
//! Each baseline is a *planner* that searches its own configuration
//! space (microbatch size, tensor-parallel degree, layer partition) and
//! returns the best feasible iteration latency on the shared execution
//! simulator — mirroring the paper's methodology ("we tested various
//! microbatch sizes (powers of 2), with the best results reported").
//!
//! Structural constraints faithfully reproduced:
//! * **Megatron-Het** — pipeline across nodes with *identical pipeline
//!   partitions*, ZeRO-2 data parallelism within nodes, tensor
//!   parallelism only for the architectures Megatron-LM supports
//!   (GPT/BERT), communication-heavy over PCIe/Ethernet.
//! * **FlashFlex** — heterogeneous pipelines (per-GPU-type stages),
//!   ZeRO-2 sharding, *memory-proportional* layer partitioning (the
//!   paper's criticism: T4 stages get V100-sized compute).
//! * **Whale** — uneven-batch data parallelism with FULL training-state
//!   replication (no sharding).
//! * **HAP** — tensor parallelism across nodes + data parallelism within
//!   nodes, no memory-constraint awareness.
//! * **FSDP** — even-everything fully sharded baseline.

pub mod flashflex;
pub mod fsdp;
pub mod hap;
pub mod megatron;
pub mod whale;

// The shared planner interface lives in `crate::plan`; every baseline
// implements `plan::Planner` and is registered in
// `plan::PlannerRegistry::with_defaults()`. Re-exported here so
// baseline call sites read naturally.
pub use crate::plan::{PlanContext, PlanDiagnostics, PlanOutcome, Planner};

/// Microbatch candidates: powers of two up to `max`.
pub fn pow2_candidates(max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut m = 1;
    while m <= max {
        v.push(m);
        m *= 2;
    }
    v
}

/// Allocator overhead multiplier applied to PyTorch-DP-family baselines
/// (FSDP, Whale) on their compute memory: caching-allocator slack,
/// transient double-buffering of gathered units and recompute peaks that
/// the planner-visible linear model does not capture.
pub const PYTORCH_FRAGMENTATION: f64 = 1.25;

/// Ring allreduce time for `bytes` over `ranks` with bottleneck `gbps`.
pub fn allreduce_time(bytes: f64, ranks: usize, gbps: f64) -> f64 {
    if ranks <= 1 {
        return 0.0;
    }
    let n = ranks as f64;
    let bw = crate::cluster::gbps_to_bytes_per_sec(gbps);
    // RS + AG, each moving (n-1)/n of the data.
    2.0 * ((n - 1.0) * 20e-6 + bytes * (n - 1.0) / (n * bw))
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::{find_model, TransformerSpec};
    use crate::perfmodel::{ClusterPerfProfile, Profiler, SyntheticOracle};

    pub struct Ctx {
        pub cluster: Cluster,
        pub model: TransformerSpec,
        pub profile: ClusterPerfProfile,
        pub oracle: SyntheticOracle,
    }

    impl Ctx {
        pub fn new(cluster: Cluster, model: &str) -> Ctx {
            let model = find_model(model).unwrap();
            let oracle = SyntheticOracle::new(&cluster, &model, 42);
            let profile =
                Profiler::default().profile(&cluster, &model, &oracle);
            Ctx { cluster, model, profile, oracle }
        }

        pub fn ctx(&self, batch: usize) -> PlanContext<'_> {
            PlanContext::new(
                &self.cluster,
                &self.model,
                &self.profile,
                &self.oracle,
                batch,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2() {
        assert_eq!(pow2_candidates(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(pow2_candidates(20), vec![1, 2, 4, 8, 16]);
        assert_eq!(pow2_candidates(1), vec![1]);
    }

    #[test]
    fn allreduce_scales() {
        let t1 = allreduce_time(1e9, 8, 50.0);
        let t2 = allreduce_time(2e9, 8, 50.0);
        assert!(t2 / t1 > 1.9 && t2 / t1 < 2.1);
        assert_eq!(allreduce_time(1e9, 1, 50.0), 0.0);
    }
}
