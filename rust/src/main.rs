//! `cephalo` — the leader entrypoint.
//!
//! Subcommands: optimize / simulate / profile / train / trace.
//! See `cephalo help` and README.md.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(cephalo::coordinator::app::main_with_args(argv));
}
