//! Leveled logging + metrics recording.
//!
//! A tiny `log`-crate substitute: global level filter, timestamped stderr
//! lines, and a `MetricsRecorder` that training/benchmark loops use to
//! accumulate named series and dump them as CSV (consumed by
//! EXPERIMENTS.md and the loss-curve artifacts).

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Log a line at `level`; prefer the `info!`/`debug!` macros.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info,
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn,
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug,
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error,
                             format_args!($($arg)*))
    };
}

/// Named time-series metrics (loss curves, throughput traces).
#[derive(Default)]
pub struct MetricsRecorder {
    series: Mutex<BTreeMap<String, Vec<(f64, f64)>>>,
    start: Option<Instant>,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self { series: Mutex::new(BTreeMap::new()), start: Some(Instant::now()) }
    }

    /// Record (x, y) on a named series.
    pub fn record(&self, name: &str, x: f64, y: f64) {
        self.series
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push((x, y));
    }

    /// Record y at wall-clock seconds since recorder creation.
    pub fn record_timed(&self, name: &str, y: f64) {
        let t = self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.record(name, t, y);
    }

    pub fn get(&self, name: &str) -> Vec<(f64, f64)> {
        self.series.lock().unwrap().get(name).cloned().unwrap_or_default()
    }

    pub fn names(&self) -> Vec<String> {
        self.series.lock().unwrap().keys().cloned().collect()
    }

    /// CSV: series,x,y per line.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,x,y\n");
        for (name, points) in self.series.lock().unwrap().iter() {
            for (x, y) in points {
                out.push_str(&format!("{name},{x},{y}\n"));
            }
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn metrics_roundtrip() {
        let m = MetricsRecorder::new();
        m.record("loss", 0.0, 6.9);
        m.record("loss", 1.0, 6.5);
        m.record("tput", 0.0, 12.0);
        assert_eq!(m.get("loss").len(), 2);
        assert_eq!(m.names(), vec!["loss".to_string(), "tput".to_string()]);
        let csv = m.to_csv();
        assert!(csv.starts_with("series,x,y\n"));
        assert!(csv.contains("loss,0,6.9"));
        assert!(csv.contains("tput,0,12"));
    }
}
