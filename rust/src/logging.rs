//! Leveled logging + metrics recording.
//!
//! A tiny `log`-crate substitute: global level filter, timestamped stderr
//! lines, and a `MetricsRecorder` that training/benchmark loops use to
//! accumulate named series and dump them as CSV (consumed by
//! EXPERIMENTS.md and the loss-curve artifacts).

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    /// Parse a CLI/env level name (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

impl std::str::FromStr for Level {
    type Err = String;

    fn from_str(s: &str) -> Result<Level, String> {
        Level::parse(s).ok_or_else(|| {
            format!("unknown log level '{s}' (error|warn|info|debug|trace)")
        })
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Resolve the effective level from an explicit `--log-level` value
/// (takes precedence; invalid is a hard error) falling back to the
/// `CEPHALO_LOG` environment variable (invalid is ignored with a
/// warning — a bad env var should not kill a training job), then to
/// the current default. Applies it via [`set_level`] and returns it.
pub fn init_level(flag: Option<&str>) -> Result<Level, String> {
    let l = match flag {
        Some(s) => s.parse::<Level>()?,
        None => match std::env::var("CEPHALO_LOG") {
            Ok(env) => match Level::parse(&env) {
                Some(l) => l,
                None => {
                    let cur = level();
                    log(
                        Level::Warn,
                        format_args!("ignoring invalid CEPHALO_LOG='{env}'"),
                    );
                    cur
                }
            },
            Err(_) => level(),
        },
    };
    set_level(l);
    Ok(l)
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Log a line at `level`; prefer the `info!`/`debug!` macros.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info,
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn,
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug,
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error,
                             format_args!($($arg)*))
    };
}

/// Named time-series metrics (loss curves, throughput traces).
#[derive(Default)]
pub struct MetricsRecorder {
    series: Mutex<BTreeMap<String, Vec<(f64, f64)>>>,
    start: Option<Instant>,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self { series: Mutex::new(BTreeMap::new()), start: Some(Instant::now()) }
    }

    /// Record (x, y) on a named series.
    pub fn record(&self, name: &str, x: f64, y: f64) {
        self.series
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push((x, y));
    }

    /// Record y at wall-clock seconds since recorder creation.
    pub fn record_timed(&self, name: &str, y: f64) {
        let t = self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.record(name, t, y);
    }

    pub fn get(&self, name: &str) -> Vec<(f64, f64)> {
        self.series.lock().unwrap().get(name).cloned().unwrap_or_default()
    }

    pub fn names(&self) -> Vec<String> {
        self.series.lock().unwrap().keys().cloned().collect()
    }

    /// CSV: series,x,y per line. Series names containing commas,
    /// quotes, or newlines are quoted (RFC-4180 style) so per-rank
    /// names like `rank 0, gather` can't shear the table.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,x,y\n");
        for (name, points) in self.series.lock().unwrap().iter() {
            let name = escape_csv_field(name);
            for (x, y) in points {
                out.push_str(&format!("{name},{x},{y}\n"));
            }
        }
        out
    }

    /// Fold another recorder's series into this one (per-rank
    /// recorders → the session-level CSV). Same-named series append
    /// in `other`'s point order.
    pub fn merge(&self, other: &MetricsRecorder) {
        let theirs = other.series.lock().unwrap();
        let mut ours = self.series.lock().unwrap();
        for (name, points) in theirs.iter() {
            ours.entry(name.clone()).or_default().extend(points.iter().copied());
        }
    }

    /// Parse [`to_csv`](Self::to_csv) output back (round-trip tests,
    /// offline analysis). Rejects malformed rows.
    pub fn from_csv(text: &str) -> Result<MetricsRecorder, String> {
        let rec = MetricsRecorder::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 {
                if line != "series,x,y" {
                    return Err(format!("bad CSV header: '{line}'"));
                }
                continue;
            }
            if line.is_empty() {
                continue;
            }
            let (name, rest) = parse_csv_field(line)
                .ok_or_else(|| format!("line {}: bad series name", i + 1))?;
            let mut nums = rest.splitn(2, ',');
            let parse = |s: Option<&str>| {
                s.and_then(|s| s.parse::<f64>().ok())
                    .ok_or_else(|| format!("line {}: bad point", i + 1))
            };
            let x = parse(nums.next())?;
            let y = parse(nums.next())?;
            rec.record(&name, x, y);
        }
        Ok(rec)
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// Quote a CSV field iff it contains a comma, quote, or newline;
/// embedded quotes double per RFC 4180.
fn escape_csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
    {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Split one CSV line into (first field unescaped, rest-after-comma).
fn parse_csv_field(line: &str) -> Option<(String, &str)> {
    if let Some(stripped) = line.strip_prefix('"') {
        let mut name = String::new();
        let mut chars = stripped.char_indices();
        while let Some((_, c)) = chars.next() {
            if c != '"' {
                name.push(c);
                continue;
            }
            return match chars.next() {
                Some((_, '"')) => {
                    name.push('"');
                    continue;
                }
                Some((j, ',')) => Some((name, &stripped[j + 1..])),
                _ => None,
            };
        }
        None
    } else {
        let (name, rest) = line.split_once(',')?;
        Some((name.to_string(), rest))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn level_names_parse_case_insensitively() {
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("Warn"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse(" debug "), Some(Level::Debug));
        assert_eq!(Level::parse("loud"), None);
        assert!("error".parse::<Level>().is_ok());
        assert!("nope".parse::<Level>().is_err());
    }

    #[test]
    fn csv_escapes_hostile_series_names_and_round_trips() {
        let m = MetricsRecorder::new();
        m.record("rank 0, gather", 1.0, 2.0);
        m.record("say \"go\"", 0.0, 1.5);
        m.record("multi\nline", 3.0, 4.0);
        m.record("plain", 5.0, 6.0);
        let csv = m.to_csv();
        assert!(csv.contains("\"rank 0, gather\",1,2\n"));
        assert!(csv.contains("\"say \"\"go\"\"\",0,1.5\n"));
        let back = MetricsRecorder::from_csv(&csv).expect("round trip");
        for name in m.names() {
            assert_eq!(back.get(&name), m.get(&name), "series '{name}'");
        }
        assert_eq!(back.names(), m.names());
        assert!(MetricsRecorder::from_csv("nope\n").is_err());
        assert!(MetricsRecorder::from_csv("series,x,y\nbad").is_err());
    }

    #[test]
    fn merge_folds_per_rank_recorders() {
        let session = MetricsRecorder::new();
        session.record("loss", 0.0, 6.9);
        let rank = MetricsRecorder::new();
        rank.record("loss", 1.0, 6.5);
        rank.record("rank1/gather_s", 0.0, 0.01);
        session.merge(&rank);
        assert_eq!(session.get("loss"), vec![(0.0, 6.9), (1.0, 6.5)]);
        assert_eq!(session.get("rank1/gather_s").len(), 1);
    }

    #[test]
    fn metrics_roundtrip() {
        let m = MetricsRecorder::new();
        m.record("loss", 0.0, 6.9);
        m.record("loss", 1.0, 6.5);
        m.record("tput", 0.0, 12.0);
        assert_eq!(m.get("loss").len(), 2);
        assert_eq!(m.names(), vec!["loss".to_string(), "tput".to_string()]);
        let csv = m.to_csv();
        assert!(csv.starts_with("series,x,y\n"));
        assert!(csv.contains("loss,0,6.9"));
        assert!(csv.contains("tput,0,12"));
    }
}
