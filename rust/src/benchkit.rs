//! Criterion-style benchmark harness (criterion substitute).
//!
//! The `benches/*.rs` binaries are `harness = false` and drive this
//! module directly: warmup, repeated timed iterations, mean/std/percentile
//! reporting, and optional CSV/markdown capture for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p50 {:>12}, p95 {:>12}, n={})",
            self.name,
            crate::util::human_secs(self.mean_s),
            crate::util::human_secs(self.p50_s),
            crate::util::human_secs(self.p95_s),
            self.iters
        )
    }
}

/// Benchmark runner with warmup + sampling.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    pub measurements: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, sample_iters: 15, measurements: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Self { warmup_iters, sample_iters, measurements: Vec::new() }
    }

    /// Time `f`, which should perform one full unit of the benchmarked
    /// work, returning a value that is black-boxed to keep the optimizer
    /// honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F)
        -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            iters: self.sample_iters,
            mean_s: stats::mean(&samples),
            std_s: stats::std_dev(&samples),
            p50_s: stats::percentile(&samples, 50.0),
            p95_s: stats::percentile(&samples, 95.0),
            min_s: stats::min(&samples),
            max_s: stats::max(&samples),
        };
        println!("{}", m.report());
        self.measurements.push(m);
        self.measurements.last().unwrap()
    }

    /// Render all measurements as a markdown table (EXPERIMENTS.md §Perf).
    pub fn render_markdown(&self, title: &str) -> String {
        let mut t = crate::util::tablefmt::Table::new(
            title,
            &["benchmark", "mean", "p50", "p95", "std", "iters"],
        );
        for m in &self.measurements {
            t.add_row(vec![
                m.name.clone(),
                crate::util::human_secs(m.mean_s),
                crate::util::human_secs(m.p50_s),
                crate::util::human_secs(m.p95_s),
                crate::util::human_secs(m.std_s),
                m.iters.to_string(),
            ]);
        }
        t.render_markdown()
    }
}

/// Identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Shared CLI surface for the self-driving benches: `--quick` shrinks
/// the run for CI smoke; `--json <path>` names the artifact file
/// (missing value is a loud error, not a silent no-op).
pub fn bench_args() -> (bool, Option<String>) {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json = args.iter().position(|a| a == "--json").map(|i| {
        let path = args
            .get(i + 1)
            .unwrap_or_else(|| panic!("--json needs a path"));
        if path.starts_with("--") {
            panic!("--json needs a path, got flag '{path}'");
        }
        path.clone()
    });
    (quick, json)
}

/// Write a bench's `{bench, quick, rows}` JSON artifact to `path` —
/// the table shape the CI `bench-smoke` job uploads.
pub fn write_json_rows(
    path: &str,
    bench: &str,
    quick: bool,
    rows: Vec<crate::util::json::Json>,
) {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str(bench.to_string()));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("rows".to_string(), Json::Arr(rows));
    std::fs::write(path, Json::Obj(root).render())
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}

/// Value of a `--<name> <value>` option on the bench command line.
pub fn bench_opt(name: &str) -> Option<String> {
    let flag = format!("--{name}");
    let args: Vec<String> = std::env::args().skip(1).collect();
    args.iter().position(|a| *a == flag).map(|i| {
        let val = args
            .get(i + 1)
            .unwrap_or_else(|| panic!("{flag} needs a value"));
        if val.starts_with("--") {
            panic!("{flag} needs a value, got flag '{val}'");
        }
        val.clone()
    })
}

// ---------------------------------------------------------------------
// Perf-trajectory gate: compare two `BENCH_*.json` artifacts of the
// same bench and fail on regression. Deterministic metrics (bytes,
// element counts, peaks, ratios) must match exactly; rate metrics
// (GB/s, steps/sec) jitter per key on quick runs, so the gate checks
// their aggregate — the geometric mean of current/baseline ratios —
// against the noise band.
// ---------------------------------------------------------------------

/// Fractional regression of the aggregate rate metric that still
/// counts as scheduler noise rather than a perf loss. Tightened from
/// 0.40 once the quick-mode benches raised their iteration counts
/// enough to average out single-scheduler-hiccup jitter.
pub const RATE_NOISE_BAND: f64 = 0.25;

/// Absolute floor pinned by the gate itself (not baseline-relative):
/// on wire-bound transport rows the shm ring fabric must sustain at
/// least this multiple of the loopback-TCP rate for BOTH collectives.
/// Promoted from a bench-side assert (ROADMAP "next spend") so a
/// regression fails `cephalo bench-gate` even when baseline and
/// current runs are equally degraded.
pub const SHM_TCP_MARGIN: f64 = 2.0;

/// Smallest `elems` at which the shm margin applies. Below this the
/// rounds are latency-bound and the ratio is scheduler noise; at
/// 2^17 elems each ring segment is ~128 KiB on the wire and the
/// fabrics separate cleanly.
pub const SHM_MARGIN_MIN_ELEMS: f64 = 131072.0;

/// Per-row floor check over a CURRENT artifact's rows: every
/// wire-bound transport row (`elems >= `[`SHM_MARGIN_MIN_ELEMS`] with
/// shm and tcp rate fields) must hold shm >= [`SHM_TCP_MARGIN`] x tcp
/// on AllGather and ReduceScatter alike. Rows without those fields —
/// every non-transport bench — are exempt. Returns one message per
/// violated (row, collective).
pub fn margin_failures(rows: &[crate::util::json::Json]) -> Vec<String> {
    use crate::util::json::Json;
    let mut out = Vec::new();
    for row in rows {
        let Json::Obj(obj) = row else { continue };
        let num =
            |k: &str| obj.get(k).and_then(|v: &Json| v.as_f64());
        let Some(elems) = num("elems") else { continue };
        if elems < SHM_MARGIN_MIN_ELEMS {
            continue;
        }
        for (shm_k, tcp_k) in [
            ("ag_shm_gbps", "ag_tcp_gbps"),
            ("rs_shm_gbps", "rs_tcp_gbps"),
        ] {
            let (Some(shm), Some(tcp)) = (num(shm_k), num(tcp_k))
            else {
                continue;
            };
            if shm < SHM_TCP_MARGIN * tcp {
                out.push(format!(
                    "elems={elems}: {shm_k} {shm:.3} < \
                     {SHM_TCP_MARGIN}x {tcp_k} {tcp:.3}"
                ));
            }
        }
    }
    out
}

/// How a metric is judged by the gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricClass {
    /// Deterministic accounting — any change is a regression.
    Exact,
    /// Throughput-like — higher is better, judged in aggregate.
    Rate,
    /// Reported but never gated.
    Info,
}

/// One flattened `(key, value)` sample from a bench row.
#[derive(Debug, Clone)]
pub struct Metric {
    pub key: String,
    pub class: MetricClass,
    pub value: f64,
}

/// Classify a row field by its name.
pub fn metric_class(field: &str) -> MetricClass {
    if field.contains("bytes")
        || field.contains("elems")
        || field.contains("peak")
        || field.contains("ratio")
    {
        MetricClass::Exact
    } else if field.contains("gbps") || field.contains("per_sec") {
        MetricClass::Rate
    } else {
        MetricClass::Info
    }
}

/// Numeric fields that name the row rather than measure it.
const ID_NUM_KEYS: [&str; 4] = ["gpu", "elems", "units", "fsdp_units"];

/// Flatten bench rows into stably-keyed metrics: each row's identity
/// prefix is built from its string fields plus the id-like numeric
/// fields, and every remaining numeric (or numeric-array) field
/// becomes one metric under that prefix. Sorted by key, so equal rows
/// always flatten identically regardless of row order.
pub fn flatten_metrics(rows: &[crate::util::json::Json]) -> Vec<Metric> {
    use crate::util::json::Json;
    let mut out: Vec<Metric> = Vec::new();
    for row in rows {
        let Json::Obj(obj) = row else { continue };
        let mut id: Vec<String> = Vec::new();
        for (k, v) in obj.iter() {
            match v {
                Json::Str(s) => id.push(format!("{k}={s}")),
                Json::Num(n) if ID_NUM_KEYS.contains(&k.as_str()) => {
                    id.push(format!("{k}={n}"));
                }
                _ => {}
            }
        }
        let prefix = id.join(",");
        let mut push = |name: String, value: f64| {
            let class = metric_class(&name);
            let key = if prefix.is_empty() {
                name
            } else {
                format!("{prefix}:{name}")
            };
            out.push(Metric { key, class, value });
        };
        for (k, v) in obj.iter() {
            match v {
                Json::Num(n) if !ID_NUM_KEYS.contains(&k.as_str()) => {
                    push(k.clone(), *n);
                }
                Json::Arr(xs) => {
                    for (i, x) in xs.iter().enumerate() {
                        if let Json::Num(n) = x {
                            push(format!("{k}[{i}]"), *n);
                        }
                    }
                }
                _ => {}
            }
        }
    }
    out.sort_by(|a, b| a.key.cmp(&b.key));
    out
}

/// The gate's verdict over one baseline/current pair.
#[derive(Debug)]
pub struct GateReport {
    /// Exact metrics whose values drifted (`key: baseline -> current`).
    pub exact_failures: Vec<String>,
    /// Baseline metrics absent from the current run.
    pub missing: Vec<String>,
    /// `(key, current/baseline)` for every rate metric.
    pub rate_ratios: Vec<(String, f64)>,
    /// Geometric mean of the rate ratios (1.0 when there are none).
    pub rate_geomean: f64,
    /// Absolute-floor violations in the CURRENT run (shm < 2x TCP on a
    /// wire-bound row — see [`margin_failures`]). Filled by
    /// [`GateReport::apply_margins`]; empty until then.
    pub margin_failures: Vec<String>,
    pub pass: bool,
}

/// Compare flattened metrics. Exact metrics must match bit for bit;
/// the aggregate rate ratio must stay within [`RATE_NOISE_BAND`].
pub fn compare_metrics(
    baseline: &[Metric],
    current: &[Metric],
) -> GateReport {
    use std::collections::BTreeMap;
    let cur: BTreeMap<&str, &Metric> =
        current.iter().map(|m| (m.key.as_str(), m)).collect();
    let mut exact_failures = Vec::new();
    let mut missing = Vec::new();
    let mut rate_ratios = Vec::new();
    for b in baseline {
        let Some(c) = cur.get(b.key.as_str()) else {
            missing.push(b.key.clone());
            continue;
        };
        match b.class {
            MetricClass::Exact => {
                if c.value.to_bits() != b.value.to_bits() {
                    exact_failures.push(format!(
                        "{}: {} -> {}",
                        b.key, b.value, c.value
                    ));
                }
            }
            MetricClass::Rate => {
                if b.value > 0.0 && c.value.is_finite() {
                    rate_ratios.push((b.key.clone(), c.value / b.value));
                }
            }
            MetricClass::Info => {}
        }
    }
    let rate_geomean = if rate_ratios.is_empty() {
        1.0
    } else {
        let logs: Vec<f64> =
            rate_ratios.iter().map(|(_, r)| r.ln()).collect();
        crate::util::stats::mean(&logs).exp()
    };
    let pass = exact_failures.is_empty()
        && missing.is_empty()
        && rate_geomean >= 1.0 - RATE_NOISE_BAND;
    GateReport {
        exact_failures,
        missing,
        rate_ratios,
        rate_geomean,
        margin_failures: Vec::new(),
        pass,
    }
}

impl GateReport {
    /// Fold the per-row shm-margin floor ([`margin_failures`]) over the
    /// CURRENT run's raw rows into the verdict. Unlike the relative
    /// checks in [`compare_metrics`], this fails even when baseline and
    /// current are identical — an absolute claim, not a drift check.
    pub fn apply_margins(&mut self, current_rows: &[crate::util::json::Json]) {
        self.margin_failures = margin_failures(current_rows);
        self.pass = self.pass && self.margin_failures.is_empty();
    }

    /// Serialize the verdict (the CI artifact).
    pub fn to_json(&self, bench: &str) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut o = BTreeMap::new();
        o.insert("bench".to_string(), Json::Str(bench.to_string()));
        o.insert("pass".to_string(), Json::Bool(self.pass));
        o.insert(
            "rate_geomean".to_string(),
            Json::Num(self.rate_geomean),
        );
        o.insert(
            "rate_noise_band".to_string(),
            Json::Num(RATE_NOISE_BAND),
        );
        o.insert(
            "exact_failures".to_string(),
            Json::Arr(
                self.exact_failures
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        );
        o.insert(
            "missing".to_string(),
            Json::Arr(
                self.missing.iter().map(|s| Json::Str(s.clone())).collect(),
            ),
        );
        o.insert(
            "shm_tcp_margin".to_string(),
            Json::Num(SHM_TCP_MARGIN),
        );
        o.insert(
            "margin_failures".to_string(),
            Json::Arr(
                self.margin_failures
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        );
        o.insert(
            "rates".to_string(),
            Json::Arr(
                self.rate_ratios
                    .iter()
                    .map(|(k, r)| {
                        let mut m = BTreeMap::new();
                        m.insert("key".to_string(), Json::Str(k.clone()));
                        m.insert("ratio".to_string(), Json::Num(*r));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }
}

/// Compare two bench artifacts on disk (same bench, two runs), write
/// the verdict JSON to `out_path` if given, and return whether the
/// gate passed.
pub fn gate_files(
    baseline_path: &str,
    current_path: &str,
    out_path: Option<&str>,
) -> Result<bool, String> {
    use crate::util::json::Json;
    let load = |p: &str| -> Result<(String, Vec<Json>), String> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| format!("reading {p}: {e}"))?;
        let j = Json::parse(&text)
            .map_err(|e| format!("parsing {p}: {e}"))?;
        let bench = j
            .get("bench")
            .and_then(|b| b.as_str())
            .ok_or_else(|| format!("{p}: missing 'bench'"))?
            .to_string();
        let rows = j
            .get("rows")
            .and_then(|r| r.as_arr())
            .ok_or_else(|| format!("{p}: missing 'rows'"))?
            .to_vec();
        Ok((bench, rows))
    };
    let (b_bench, b_rows) = load(baseline_path)?;
    let (c_bench, c_rows) = load(current_path)?;
    if b_bench != c_bench {
        return Err(format!(
            "bench mismatch: baseline '{b_bench}' vs current '{c_bench}'"
        ));
    }
    let mut report = compare_metrics(
        &flatten_metrics(&b_rows),
        &flatten_metrics(&c_rows),
    );
    report.apply_margins(&c_rows);
    if let Some(path) = out_path {
        std::fs::write(path, report.to_json(&b_bench).render())
            .map_err(|e| format!("writing {path}: {e}"))?;
    }
    for f in &report.exact_failures {
        println!("REGRESSION (exact): {f}");
    }
    for m in &report.missing {
        println!("REGRESSION (missing metric): {m}");
    }
    for m in &report.margin_failures {
        println!("REGRESSION (margin): {m}");
    }
    println!(
        "{}: {} exact drift(s), {} missing, {} margin, rate geomean \
         {:.3} (band {:.2}) -> {}",
        b_bench,
        report.exact_failures.len(),
        report.missing.len(),
        report.margin_failures.len(),
        report.rate_geomean,
        RATE_NOISE_BAND,
        if report.pass { "PASS" } else { "FAIL" }
    );
    Ok(report.pass)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(1, 5);
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.p50_s && m.p50_s <= m.max_s);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn collects_multiple_measurements() {
        let mut b = Bencher::new(0, 3);
        b.bench("a", || 1 + 1);
        b.bench("b", || 2 + 2);
        assert_eq!(b.measurements.len(), 2);
        let md = b.render_markdown("t");
        assert!(md.contains("| a |"));
        assert!(md.contains("| b |"));
    }

    use crate::util::json::Json;
    use std::collections::BTreeMap;

    fn row(pairs: &[(&str, Json)]) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v.clone());
        }
        Json::Obj(m)
    }

    fn sample_rows(gbps: f64, bytes: f64) -> Vec<Json> {
        vec![
            row(&[
                ("elems", Json::Num(1024.0)),
                ("bytes_per_round", Json::Num(bytes)),
                ("ag_local_gbps", Json::Num(gbps)),
            ]),
            row(&[
                ("scale", Json::Str("executed".into())),
                ("residency", Json::Str("sharded".into())),
                (
                    "param_bytes",
                    Json::Arr(vec![Json::Num(8.0), Json::Num(4.0)]),
                ),
                ("steps_per_sec", Json::Num(100.0)),
            ]),
        ]
    }

    #[test]
    fn metrics_flatten_with_stable_keys_and_classes() {
        let ms = flatten_metrics(&sample_rows(2.0, 4096.0));
        let keys: Vec<&str> =
            ms.iter().map(|m| m.key.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "elems=1024:ag_local_gbps",
                "elems=1024:bytes_per_round",
                "residency=sharded,scale=executed:param_bytes[0]",
                "residency=sharded,scale=executed:param_bytes[1]",
                "residency=sharded,scale=executed:steps_per_sec",
            ]
        );
        assert_eq!(ms[0].class, MetricClass::Rate);
        assert_eq!(ms[1].class, MetricClass::Exact);
        assert_eq!(ms[2].class, MetricClass::Exact);
        assert_eq!(ms[4].class, MetricClass::Rate);
    }

    #[test]
    fn gate_passes_identical_runs_and_rate_jitter_within_band() {
        let base = flatten_metrics(&sample_rows(2.0, 4096.0));
        let same = compare_metrics(&base, &base);
        assert!(same.pass);
        assert_eq!(same.rate_geomean, 1.0);
        // A 20% aggregate rate dip is inside the 25% noise band.
        let jittered = flatten_metrics(&{
            let mut rows = sample_rows(1.6, 4096.0);
            if let Json::Obj(m) = &mut rows[1] {
                m.insert("steps_per_sec".into(), Json::Num(80.0));
            }
            rows
        });
        assert!(compare_metrics(&base, &jittered).pass);
    }

    #[test]
    fn gate_fails_exact_drift_missing_metrics_and_rate_collapse() {
        let base = flatten_metrics(&sample_rows(2.0, 4096.0));
        // Deterministic accounting drifted: always a regression.
        let drifted = flatten_metrics(&sample_rows(2.0, 8192.0));
        let r = compare_metrics(&base, &drifted);
        assert!(!r.pass);
        assert_eq!(r.exact_failures.len(), 1);
        // A metric vanished.
        let fewer = flatten_metrics(&sample_rows(2.0, 4096.0)[..1]);
        assert!(!compare_metrics(&base, &fewer).pass);
        // Rates collapsed beyond the band.
        let slow = flatten_metrics(&{
            let mut rows = sample_rows(1.0, 4096.0);
            if let Json::Obj(m) = &mut rows[1] {
                m.insert("steps_per_sec".into(), Json::Num(50.0));
            }
            rows
        });
        let r = compare_metrics(&base, &slow);
        assert!(!r.pass);
        assert!(r.exact_failures.is_empty());
        assert!((r.rate_geomean - 0.5).abs() < 1e-12);
    }

    fn transport_row(elems: f64, shm: f64, tcp: f64) -> Json {
        row(&[
            ("elems", Json::Num(elems)),
            ("ag_tcp_gbps", Json::Num(tcp)),
            ("ag_shm_gbps", Json::Num(shm)),
            ("rs_tcp_gbps", Json::Num(tcp)),
            ("rs_shm_gbps", Json::Num(shm * 1.1)),
        ])
    }

    #[test]
    fn shm_margin_floor_is_per_row_and_wire_bound_only() {
        // Latency-bound rows (below 2^17 elems) are exempt however bad
        // the ratio; non-transport rows without the rate fields are
        // skipped entirely.
        let ok = vec![
            transport_row(1024.0, 1.0, 3.0), // small: exempt
            transport_row(131072.0, 8.0, 3.0), // 2.67x: holds
            sample_rows(2.0, 4096.0)[1].clone(), // no shm/tcp fields
        ];
        assert!(margin_failures(&ok).is_empty());
        // One wire-bound row below 2x fails on BOTH collectives; the
        // healthy row alongside it stays silent.
        let bad = vec![
            transport_row(131072.0, 5.0, 3.0), // 1.67x: violated
            transport_row(262144.0, 9.0, 3.0),
        ];
        let fails = margin_failures(&bad);
        assert_eq!(fails.len(), 2);
        assert!(fails[0].contains("ag_shm_gbps"), "{}", fails[0]);
        assert!(fails[1].contains("rs_shm_gbps"), "{}", fails[1]);
        assert!(fails[0].contains("elems=131072"));
    }

    #[test]
    fn shm_margin_violation_fails_the_gate_verdict_json() {
        // Satellite: the floor lives in the GATE, so identical
        // baseline/current artifacts still FAIL when both violate it —
        // a drift check alone would wave this through.
        let dir = std::env::temp_dir();
        let bp = dir.join("cephalo_margin_base.json");
        let cp = dir.join("cephalo_margin_cur.json");
        let vp = dir.join("cephalo_margin_verdict.json");
        let write = |p: &std::path::Path, rows: Vec<Json>| {
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(), Json::Str("transport".into()));
            root.insert("rows".to_string(), Json::Arr(rows));
            std::fs::write(p, Json::Obj(root).render()).unwrap();
        };
        let degraded = vec![transport_row(131072.0, 4.0, 3.0)]; // 1.33x
        write(&bp, degraded.clone());
        write(&cp, degraded);
        let pass = gate_files(
            bp.to_str().unwrap(),
            cp.to_str().unwrap(),
            Some(vp.to_str().unwrap()),
        )
        .unwrap();
        assert!(!pass, "shm below 2x TCP must fail even with no drift");
        let verdict =
            Json::parse(&std::fs::read_to_string(&vp).unwrap()).unwrap();
        assert_eq!(verdict.get("pass").unwrap().as_bool(), Some(false));
        assert_eq!(
            verdict.get("shm_tcp_margin").unwrap().as_f64(),
            Some(SHM_TCP_MARGIN)
        );
        let margins = verdict
            .get("margin_failures")
            .and_then(|m| m.as_arr())
            .expect("verdict carries margin_failures");
        assert_eq!(margins.len(), 2);
        assert!(margins[0]
            .as_str()
            .unwrap()
            .contains("ag_shm_gbps"));
        // At a healthy margin the same pair passes and the verdict's
        // failure list is empty.
        let healthy = vec![transport_row(131072.0, 7.5, 3.0)]; // 2.5x
        write(&bp, healthy.clone());
        write(&cp, healthy);
        assert!(gate_files(
            bp.to_str().unwrap(),
            cp.to_str().unwrap(),
            Some(vp.to_str().unwrap()),
        )
        .unwrap());
        let verdict =
            Json::parse(&std::fs::read_to_string(&vp).unwrap()).unwrap();
        assert_eq!(verdict.get("pass").unwrap().as_bool(), Some(true));
        assert!(verdict
            .get("margin_failures")
            .and_then(|m| m.as_arr())
            .unwrap()
            .is_empty());
        for p in [&bp, &cp, &vp] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn gate_files_round_trip_writes_the_verdict() {
        let dir = std::env::temp_dir();
        let bp = dir.join("cephalo_gate_base.json");
        let cp = dir.join("cephalo_gate_cur.json");
        let vp = dir.join("cephalo_gate_verdict.json");
        let write = |p: &std::path::Path, rows: Vec<Json>| {
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(), Json::Str("t".into()));
            root.insert("quick".to_string(), Json::Bool(true));
            root.insert("rows".to_string(), Json::Arr(rows));
            std::fs::write(p, Json::Obj(root).render()).unwrap();
        };
        write(&bp, sample_rows(2.0, 4096.0));
        write(&cp, sample_rows(1.9, 4096.0));
        let pass = gate_files(
            bp.to_str().unwrap(),
            cp.to_str().unwrap(),
            Some(vp.to_str().unwrap()),
        )
        .unwrap();
        assert!(pass);
        let verdict =
            Json::parse(&std::fs::read_to_string(&vp).unwrap()).unwrap();
        assert_eq!(verdict.get("pass").unwrap().as_bool(), Some(true));
        assert_eq!(verdict.get("bench").unwrap().as_str(), Some("t"));
        assert!(verdict.get("rate_geomean").unwrap().as_f64().is_some());
        // Mismatched bench names are a loud error, not a silent pass.
        write(&cp, sample_rows(2.0, 4096.0));
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("other".into()));
        root.insert("rows".to_string(), Json::Arr(Vec::new()));
        std::fs::write(&bp, Json::Obj(root).render()).unwrap();
        assert!(gate_files(
            bp.to_str().unwrap(),
            cp.to_str().unwrap(),
            None
        )
        .is_err());
        for p in [&bp, &cp, &vp] {
            std::fs::remove_file(p).ok();
        }
    }
}
