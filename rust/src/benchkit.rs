//! Criterion-style benchmark harness (criterion substitute).
//!
//! The `benches/*.rs` binaries are `harness = false` and drive this
//! module directly: warmup, repeated timed iterations, mean/std/percentile
//! reporting, and optional CSV/markdown capture for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p50 {:>12}, p95 {:>12}, n={})",
            self.name,
            crate::util::human_secs(self.mean_s),
            crate::util::human_secs(self.p50_s),
            crate::util::human_secs(self.p95_s),
            self.iters
        )
    }
}

/// Benchmark runner with warmup + sampling.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    pub measurements: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, sample_iters: 15, measurements: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Self { warmup_iters, sample_iters, measurements: Vec::new() }
    }

    /// Time `f`, which should perform one full unit of the benchmarked
    /// work, returning a value that is black-boxed to keep the optimizer
    /// honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F)
        -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            iters: self.sample_iters,
            mean_s: stats::mean(&samples),
            std_s: stats::std_dev(&samples),
            p50_s: stats::percentile(&samples, 50.0),
            p95_s: stats::percentile(&samples, 95.0),
            min_s: stats::min(&samples),
            max_s: stats::max(&samples),
        };
        println!("{}", m.report());
        self.measurements.push(m);
        self.measurements.last().unwrap()
    }

    /// Render all measurements as a markdown table (EXPERIMENTS.md §Perf).
    pub fn render_markdown(&self, title: &str) -> String {
        let mut t = crate::util::tablefmt::Table::new(
            title,
            &["benchmark", "mean", "p50", "p95", "std", "iters"],
        );
        for m in &self.measurements {
            t.add_row(vec![
                m.name.clone(),
                crate::util::human_secs(m.mean_s),
                crate::util::human_secs(m.p50_s),
                crate::util::human_secs(m.p95_s),
                crate::util::human_secs(m.std_s),
                m.iters.to_string(),
            ]);
        }
        t.render_markdown()
    }
}

/// Identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Shared CLI surface for the self-driving benches: `--quick` shrinks
/// the run for CI smoke; `--json <path>` names the artifact file
/// (missing value is a loud error, not a silent no-op).
pub fn bench_args() -> (bool, Option<String>) {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json = args.iter().position(|a| a == "--json").map(|i| {
        let path = args
            .get(i + 1)
            .unwrap_or_else(|| panic!("--json needs a path"));
        if path.starts_with("--") {
            panic!("--json needs a path, got flag '{path}'");
        }
        path.clone()
    });
    (quick, json)
}

/// Write a bench's `{bench, quick, rows}` JSON artifact to `path` —
/// the table shape the CI `bench-smoke` job uploads.
pub fn write_json_rows(
    path: &str,
    bench: &str,
    quick: bool,
    rows: Vec<crate::util::json::Json>,
) {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str(bench.to_string()));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("rows".to_string(), Json::Arr(rows));
    std::fs::write(path, Json::Obj(root).render())
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(1, 5);
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.p50_s && m.p50_s <= m.max_s);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn collects_multiple_measurements() {
        let mut b = Bencher::new(0, 3);
        b.bench("a", || 1 + 1);
        b.bench("b", || 2 + 2);
        assert_eq!(b.measurements.len(), 2);
        let md = b.render_markdown("t");
        assert!(md.contains("| a |"));
        assert!(md.contains("| b |"));
    }
}
