//! Command-line argument parsing (clap substitute).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! `--switch`, and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for one subcommand.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// Parsed arguments for a subcommand.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub opts: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Parse `argv` (without the program name) against `specs`.
/// Unknown `--options` are an error; positionals pass through.
pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, String> {
    let mut args = Args::default();
    // Seed defaults.
    for spec in specs {
        if let Some(d) = spec.default {
            args.opts.insert(spec.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline_val) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| format!("unknown option --{name}"))?;
            if spec.is_switch {
                if inline_val.is_some() {
                    return Err(format!("--{name} takes no value"));
                }
                args.switches.push(name.to_string());
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| format!("--{name} needs a value"))?
                    }
                };
                args.opts.insert(name.to_string(), val);
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("{cmd} — {about}\n\noptions:\n");
    for s in specs {
        let head = if s.is_switch {
            format!("  --{}", s.name)
        } else {
            format!("  --{} <value>", s.name)
        };
        let default = s
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("{head:<32} {}{default}\n", s.help));
    }
    out
}

pub const fn opt(
    name: &'static str,
    help: &'static str,
    default: Option<&'static str>,
) -> OptSpec {
    OptSpec { name, help, default, is_switch: false }
}

pub const fn switch(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_switch: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            opt("batch", "global batch size", Some("128")),
            opt("cluster", "cluster name", None),
            switch("verbose", "debug logging"),
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_usize("batch"), Some(128));
        assert_eq!(a.get("cluster"), None);
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&sv(&["--batch", "256", "--cluster=a"]), &specs())
            .unwrap();
        assert_eq!(a.get_usize("batch"), Some(256));
        assert_eq!(a.get("cluster"), Some("a"));
    }

    #[test]
    fn switches_and_positionals() {
        let a = parse(&sv(&["train", "--verbose", "extra"]), &specs())
            .unwrap();
        assert!(a.has("verbose"));
        assert!(!a.has("missing"));
        assert_eq!(a.positional, vec!["train", "extra"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&sv(&["--nope"]), &specs()).is_err());
        assert!(parse(&sv(&["--batch"]), &specs()).is_err()); // missing value
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("train", "run training", &specs());
        assert!(u.contains("--batch"));
        assert!(u.contains("default: 128"));
        assert!(u.contains("--verbose"));
    }
}
