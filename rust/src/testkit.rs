//! Mini property-testing framework (proptest substitute).
//!
//! The offline dependency closure has no `proptest`, so Cephalo carries a
//! small deterministic property-test harness with the same methodology:
//! run a property over many PRNG-generated cases; on failure, retry with
//! progressively "smaller" regenerated cases (shrinking-lite) and report
//! the smallest failing seed so the case is reproducible.
//!
//! ```ignore
//! // (`ignore`: doctest binaries do not inherit the xla rpath flags,
//! // so they cannot load libxla_extension.so; the same example runs
//! // as a unit test below.)
//! use cephalo::testkit::{check, Gen};
//! check("sum is commutative", 256, |g: &mut Gen| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::prng::Rng;

/// The canonical 2-GPU toy cluster (one T4 + one V100 on a single
/// node): small enough for brute-force test oracles, heterogeneous
/// enough to exercise uneven compute/state division. Shared by the DP
/// brute-force comparison and the plan-subsystem parity tests.
pub fn tiny_cluster() -> crate::cluster::Cluster {
    use crate::cluster::catalog::find;
    use crate::cluster::{Cluster, Node};
    Cluster {
        name: "tiny".into(),
        nodes: vec![Node {
            name: "n0".into(),
            gpus: vec![find("T4").unwrap(), find("V100").unwrap()],
            intra_bw_gbps: 64.0,
        }],
        inter_bw_gbps: 50.0,
    }
}

/// A 3-GPU variant of [`tiny_cluster`] (T4 + V100 + P40 on one node):
/// the smallest cluster whose ring has a middle rank, used by the
/// distributed-session parity tests (3 transport ranks).
pub fn tiny_cluster3() -> crate::cluster::Cluster {
    use crate::cluster::catalog::find;
    use crate::cluster::{Cluster, Node};
    Cluster {
        name: "tiny3".into(),
        nodes: vec![Node {
            name: "n0".into(),
            gpus: vec![
                find("T4").unwrap(),
                find("V100").unwrap(),
                find("P40").unwrap(),
            ],
            intra_bw_gbps: 64.0,
        }],
        inter_bw_gbps: 50.0,
    }
}

/// An 8x-P40 single-node cluster for the parameter-residency window
/// tests (n = 8 is the smallest uniform size where the window below
/// exists unconditionally). Pair with [`apply_residency_window`].
pub fn window8_cluster() -> crate::cluster::Cluster {
    use crate::cluster::catalog::find;
    use crate::cluster::{Cluster, Node};
    Cluster {
        name: "window8".into(),
        nodes: vec![Node {
            name: "n0".into(),
            gpus: vec![find("P40").unwrap(); 8],
            intra_bw_gbps: 64.0,
        }],
        inter_bw_gbps: 50.0,
    }
}

/// Shrink a fitted profile's capacities onto the residency window:
/// each GPU fits m = 1 compute plus 1.3x an even share of the fully
/// sharded 16 B/param state — but NOT a replicated 4 B/param weight
/// copy. With n GPUs the window needs `4 > 1.3 x 16/n`, i.e. n > 5.2,
/// so on [`window8_cluster`] it exists for ANY oracle magnitudes, by
/// construction. Used by the planner-residency acceptance tests
/// (`optimizer::dp` unit + `tests/plan_system.rs` sweep).
pub fn apply_residency_window(
    profile: &mut crate::perfmodel::ClusterPerfProfile,
) {
    let n = profile.per_gpu.len() as f64;
    let share = crate::memory::state_bytes(profile.total_params) / n;
    for g in profile.per_gpu.iter_mut() {
        let usable = g.mem.predict(1) + 1.3 * share;
        g.capacity = usable / crate::memory::MEM_UTIL_CAP;
    }
}

/// Shrink a fitted profile's capacities onto the FSDP-UNIT residency
/// window: each GPU fits m = 1 compute plus 1.1x (the double-buffered
/// unit pair `2 x 4 B/param / units` + an even share of the fully
/// sharded 16 B/param state) — but NOT the whole-model gather buffer
/// (a full 4 B/param on every rank). On [`window8_cluster`] with
/// `units` >= 16 the window is strictly wider than the one
/// [`apply_residency_window`] builds, so it exists whenever that one
/// does. Used by the FSDP-unit capacity acceptance tests
/// (`tests/plan_system.rs`).
pub fn apply_unit_residency_window(
    profile: &mut crate::perfmodel::ClusterPerfProfile,
    units: usize,
) {
    let n = profile.per_gpu.len() as f64;
    let p = profile.total_params;
    let fixed = crate::memory::ParamResidency::UnitSharded { units }
        .fixed_bytes(p);
    let share = crate::memory::state_bytes(p) / n;
    for g in profile.per_gpu.iter_mut() {
        let usable = g.mem.predict(1) + 1.1 * (fixed + share);
        g.capacity = usable / crate::memory::MEM_UTIL_CAP;
    }
}

/// Per-case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0,1]: shrink attempts re-run with smaller sizes.
    pub size: f64,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Rng::new(seed), size, case_seed: seed }
    }

    /// usize in [lo, hi], biased smaller when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.size).ceil() as usize;
        let hi_eff = lo + scaled.min(span);
        if hi_eff == lo {
            lo
        } else {
            self.rng.range(lo, hi_eff + 1)
        }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64() * self.size.max(0.05)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }

    /// A vector of f32 in [-scale, scale].
    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len)
            .map(|_| (self.rng.f32() * 2.0 - 1.0) * scale)
            .collect()
    }

    /// A normalized ratio vector of length n (sums to 1, entries >= 0).
    pub fn ratios(&mut self, n: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = (0..n).map(|_| self.rng.f64() + 1e-3).collect();
        let total: f64 = xs.iter().sum();
        for x in xs.iter_mut() {
            *x /= total;
        }
        xs
    }

    /// A ratio vector of length n where a random subset of entries is
    /// EXACTLY zero (at least one stays positive) — the `r_i = 0`
    /// empty-shard layouts the ring collectives and migration planner
    /// must survive. Not normalized; `ShardLayout::by_ratios` does that.
    pub fn sparse_ratios(&mut self, n: usize) -> Vec<f64> {
        let mut xs = self.ratios(n);
        let keep = self.rng.range(0, n);
        for (i, x) in xs.iter_mut().enumerate() {
            if i != keep && self.rng.bool(0.5) {
                *x = 0.0;
            }
        }
        xs
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated cases. Panics (failing the enclosing
/// test) with the reproducing seed on the first failure, after attempting
/// smaller-size reproductions.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if let Err(payload) = result {
            // Shrinking-lite: try the same seed at smaller sizes and
            // report the smallest size that still fails.
            let mut min_failing_size = 1.0;
            for &size in &[0.05, 0.1, 0.25, 0.5, 0.75] {
                let failed = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                })
                .is_err();
                if failed {
                    min_failing_size = size;
                    break;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} \
                 (seed={seed:#x}, min_failing_size={min_failing_size}): {msg}"
            );
        }
    }
}

// Stable name->seed derivation shares the one FNV-1a in `util`.
use crate::util::fnv1a;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_cluster_shape() {
        let c = tiny_cluster();
        assert_eq!(c.num_gpus(), 2);
        assert!(!c.is_homogeneous());
    }

    #[test]
    fn passing_property_runs_all_cases() {
        check("add-commutes", 64, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 8, |_g| {
            panic!("nope");
        });
    }

    #[test]
    fn ratios_sum_to_one() {
        check("ratios-normalized", 64, |g| {
            let n = g.usize_in(1, 16);
            let r = g.ratios(n);
            assert_eq!(r.len(), n);
            let s: f64 = r.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(r.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    fn usize_in_respects_bounds() {
        check("usize-bounds", 128, |g| {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
        });
    }

    #[test]
    fn deterministic_given_name() {
        // Same property name => same sequence of generated values.
        let mut first = Vec::new();
        check("determinism-probe", 4, |g| {
            // record through a thread local to avoid capture issues
            FIRST.with(|f| f.borrow_mut().push(g.usize_in(0, 1_000_000)));
        });
        FIRST.with(|f| first.extend(f.borrow().iter().copied()));
        FIRST.with(|f| f.borrow_mut().clear());
        let mut second = Vec::new();
        check("determinism-probe", 4, |g| {
            FIRST.with(|f| f.borrow_mut().push(g.usize_in(0, 1_000_000)));
        });
        FIRST.with(|f| second.extend(f.borrow().iter().copied()));
        assert_eq!(first, second);
    }

    thread_local! {
        static FIRST: std::cell::RefCell<Vec<usize>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }
}
