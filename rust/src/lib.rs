//! # Cephalo — heterogeneous-cluster transformer training
//!
//! A Rust + JAX + Pallas reproduction of *“Cephalo: Harnessing
//! Heterogeneous GPU Clusters for Training Transformer Models”* (Guo et
//! al., 2024).
//!
//! Cephalo decouples **compute** assignment (per-GPU batch size) from
//! **memory** assignment (training-state shard ratio) on top of a fully
//! sharded data-parallel (FSDP) runtime, adds *layered gradient
//! accumulation* with communication overlap, and activation
//! checkpointing + asynchronous CPU offloading — then jointly optimizes
//! all of it with a dynamic program over profiled performance models.
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: cluster/topology modeling,
//!   profiler + performance models, the DP optimizer, the execution
//!   simulator with per-device compute/comm/offload streams, the
//!   heterogeneous baselines, and a real numeric training engine driving
//!   AOT-compiled JAX computations through PJRT (behind the `xla`
//!   feature — see DESIGN.md §Runtime).
//! * **L2 (`python/compile/model.py`)** — the transformer fwd/bwd in
//!   JAX, lowered once to HLO text (`artifacts/`).
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels (flash
//!   attention, fused FFN, fused LayerNorm) called by L2.
//!
//! Every planning strategy — the Cephalo DP solver, the five baseline
//! systems, and the §4.4 ablations — implements the [`plan::Planner`]
//! trait and is reachable through [`plan::PlannerRegistry`]; solved
//! plans are memoized in a content-addressed [`plan::PlanCache`] (what
//! makes elastic re-planning over recurring memberships near-free) and
//! grids of (planner, batch) solves run in parallel via
//! [`plan::sweep`]. See DESIGN.md §Plan subsystem.
//!
//! Symmetrically, every training-step backend implements the
//! [`exec::StepExecutor`] trait: the dependency-free
//! [`exec::NativeExecutor`] runs the full numeric FSDP pipeline (uneven
//! split → grad accumulation → ring ReduceScatter → sharded Adam → ring
//! AllGather) in the default build, and the PJRT engine is just another
//! backend behind the same trait (`xla` feature). On top of both,
//! [`coordinator::session::Session`] runs LIVE elastic training:
//! aws-trace churn → re-plan through the registry + cache → apply the
//! state-migration transfer list → resume. See DESIGN.md §Exec
//! subsystem.
//!
//! Rank-to-rank communication is its own subsystem ([`transport`]): a
//! [`transport::Transport`] trait with channel (`local`) and socket
//! (`tcp`) backends, segmented ring collectives executed as real peer
//! messages ([`transport::collectives`]), and an SPMD multi-process
//! trainer ([`transport::dist`]) behind `cephalo worker` /
//! `--transport local|tcp`. The wire is bitwise-invisible: every
//! backend reproduces the in-process trajectory bit for bit
//! (DESIGN.md §Transport subsystem, invariant 10).

pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod configfmt;
pub mod logging;
pub mod memory;
pub mod model;
pub mod perfmodel;
pub mod telemetry;
pub mod testkit;
pub mod util;

pub mod baselines;
pub mod collectives;
pub mod coordinator;
pub mod exec;
pub mod plan;
pub mod runtime;
pub mod trainer;
pub mod transport;
pub mod optimizer;
pub mod sharding;
pub mod sim;
