//! Runtime telemetry: phase-level span tracing, per-lane fabric
//! counters, and planned-vs-measured skew inputs — the observe side of
//! Cephalo's observe→plan loop, zero-dependency like everything else.
//!
//! Three pieces:
//!
//! * **[`Tracer`]** — a process-global span tracer. Hot paths open
//!   RAII [`Span`]s (categories below) or drop [`instant`] markers;
//!   events land in THREAD-LOCAL buffers (one relaxed atomic load when
//!   tracing is off, no lock when it is on) and drain into the global
//!   sink at step boundaries ([`drain`]), on buffer overflow, or at
//!   thread exit. [`write_chrome_trace`] renders the sink as Chrome
//!   trace-event JSON — loadable in Perfetto / `chrome://tracing` —
//!   with fabric-counter metadata attached.
//! * **[`FabricCounters`]** — always-on relaxed atomics counting
//!   bytes/frames per edge class (shm vs tcp), CRC failures, seq-dedup
//!   drops, resends, heartbeats and liveness-probe RTT. Snapshotted
//!   into session reports and trace metadata.
//! * **[`PhaseBreakdown`]** — the per-step phase timing record
//!   (gather / compute / reduce-scatter / overlap-wait / optimizer)
//!   carried in `StepStats` and in the STEP wire reply, so the
//!   coordinator can assemble a cross-rank timeline and a
//!   planned-vs-measured skew report.
//!
//! **Invariant 14 (DESIGN.md): telemetry is bitwise-invisible.** Spans
//! and counters only *read* clocks and *count* traffic; the phase
//! fields ride the STEP reply UNCONDITIONALLY (the wire format does
//! not depend on whether tracing is enabled), so a run with tracing
//! on, off, or toggled mid-session produces bit-identical parameters.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::error::{anyhow, Result};
use crate::util::json::Json;

/// Span categories (the `cat` field in the exported trace).
pub const CAT_GATHER: &str = "gather";
pub const CAT_COMPUTE: &str = "compute";
pub const CAT_REDUCE_SCATTER: &str = "reduce_scatter";
pub const CAT_OVERLAP_WAIT: &str = "overlap_wait";
pub const CAT_OPTIMIZER: &str = "optimizer";
pub const CAT_MIGRATE: &str = "migrate";
pub const CAT_REPLAN: &str = "replan";
pub const CAT_DETECT: &str = "detect";
pub const CAT_RECOVER: &str = "recover";
/// Instant-event category for injected chaos faults.
pub const CAT_FAULT: &str = "fault";
/// Instant-event category for heartbeat / liveness suspicions.
pub const CAT_SUSPECT: &str = "suspect";

/// Trace "process" holding locally recorded spans (tid = rank).
pub const PID_LOCAL: u32 = 0;
/// Trace "process" holding the coordinator-assembled cross-rank step
/// timeline (synthesized from the phase fields in STEP replies; kept
/// on its own pid so it never partially overlaps rank-local spans).
pub const PID_TIMELINE: u32 = 1;

/// One trace event: a complete span (`dur_us: Some`) or an instant.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: String,
    pub cat: &'static str,
    /// Microseconds since the process trace epoch.
    pub ts_us: f64,
    /// `Some(duration)` = complete span (ph "X"); `None` = instant.
    pub dur_us: Option<f64>,
    pub pid: u32,
    /// Track id — the RANK that produced the event.
    pub tid: u64,
}

/// Thread-local event buffer; drains to the global sink at step
/// boundaries, when full, and (via `Drop`) at thread exit — so
/// heartbeat/reader threads that never see a step boundary still
/// surface their events.
struct LocalBuf {
    rank: u64,
    events: Vec<Event>,
}

const LOCAL_FLUSH_AT: usize = 4096;

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.events.is_empty() {
            if let Ok(mut sink) = tracer().sink.lock() {
                sink.append(&mut self.events);
            }
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> =
        RefCell::new(LocalBuf { rank: 0, events: Vec::new() });
}

/// The process-global tracer: an enabled flag plus the drained sink.
pub struct Tracer {
    enabled: AtomicBool,
    sink: Mutex<Vec<Event>>,
}

static TRACER: Tracer =
    Tracer { enabled: AtomicBool::new(false), sink: Mutex::new(Vec::new()) };

/// The process-global [`Tracer`].
pub fn tracer() -> &'static Tracer {
    &TRACER
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (first telemetry call).
pub fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

impl Tracer {
    pub fn enable(&self) {
        // Pin the epoch before the first span so timestamps are small.
        let _ = epoch();
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// Whether span recording is on (one relaxed load — the entire
    /// cost of a span site while tracing is off).
    pub fn on(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    fn push(&self, e: Event) {
        LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            l.events.push(e);
            if l.events.len() >= LOCAL_FLUSH_AT {
                let mut drained = std::mem::take(&mut l.events);
                if let Ok(mut sink) = self.sink.lock() {
                    sink.append(&mut drained);
                }
            }
        });
    }
}

/// Enable span recording process-wide.
pub fn enable() {
    tracer().enable();
}

/// Disable span recording (already-recorded events stay buffered).
pub fn disable() {
    tracer().disable();
}

/// Whether span recording is on.
pub fn on() -> bool {
    tracer().on()
}

/// Tag the CURRENT THREAD's events with `rank` (the trace `tid`).
pub fn set_rank(rank: usize) {
    LOCAL.with(|l| l.borrow_mut().rank = rank as u64);
}

/// Drain the current thread's buffer into the global sink — called at
/// step boundaries so export sees everything without locking hot paths.
pub fn drain() {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if !l.events.is_empty() {
            let mut drained = std::mem::take(&mut l.events);
            if let Ok(mut sink) = tracer().sink.lock() {
                sink.append(&mut drained);
            }
        }
    });
}

/// Steal every buffered event (current thread + global sink), sorted
/// by timestamp. Used by export and tests; also resets the sink.
pub fn take_events() -> Vec<Event> {
    drain();
    let mut events = match tracer().sink.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    };
    events.sort_by(|a, b| {
        a.ts_us.partial_cmp(&b.ts_us).unwrap_or(std::cmp::Ordering::Equal)
    });
    events
}

/// Drop every buffered event and disable tracing — test isolation.
pub fn reset() {
    disable();
    let _ = take_events();
}

/// An RAII span: records a complete ("X") event over its lifetime.
/// Inert (and allocation-free) while tracing is off.
#[must_use = "a span records its duration when dropped"]
pub struct Span {
    inner: Option<(&'static str, String, f64)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((cat, name, start_us)) = self.inner.take() {
            let (rank, dur) = (current_rank(), now_us() - start_us);
            tracer().push(Event {
                name,
                cat,
                ts_us: start_us,
                dur_us: Some(dur),
                pid: PID_LOCAL,
                tid: rank,
            });
        }
    }
}

fn current_rank() -> u64 {
    LOCAL.with(|l| l.borrow().rank)
}

/// Open a span in `cat`; it closes (and records) when dropped.
pub fn span(cat: &'static str, name: &str) -> Span {
    if !on() {
        return Span { inner: None };
    }
    Span { inner: Some((cat, name.to_string(), now_us())) }
}

/// Record an instant event (chaos faults, suspicions, marks).
pub fn instant(cat: &'static str, name: &str) {
    if !on() {
        return;
    }
    tracer().push(Event {
        name: name.to_string(),
        cat,
        ts_us: now_us(),
        dur_us: None,
        pid: PID_LOCAL,
        tid: current_rank(),
    });
}

/// Record a complete span with EXPLICIT coordinates — the coordinator
/// uses this to lay out the cross-rank step timeline from the phase
/// durations carried in STEP replies.
pub fn complete_at(
    cat: &'static str,
    name: &str,
    pid: u32,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
) {
    if !on() {
        return;
    }
    tracer().push(Event {
        name: name.to_string(),
        cat,
        ts_us,
        dur_us: Some(dur_us),
        pid,
        tid,
    });
}

/// Per-step phase timings (seconds). Measured UNCONDITIONALLY — the
/// STEP wire reply always carries these five fields, so enabling or
/// disabling tracing can never change wire behavior (invariant 14).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub gather_s: f64,
    pub compute_s: f64,
    pub reduce_scatter_s: f64,
    pub overlap_wait_s: f64,
    pub optimizer_s: f64,
}

impl PhaseBreakdown {
    pub const WIRE_FIELDS: usize = 5;

    /// Wire order of the five phase fields.
    pub fn to_array(self) -> [f64; 5] {
        [
            self.gather_s,
            self.compute_s,
            self.reduce_scatter_s,
            self.overlap_wait_s,
            self.optimizer_s,
        ]
    }

    pub fn from_array(a: [f64; 5]) -> PhaseBreakdown {
        PhaseBreakdown {
            gather_s: a[0],
            compute_s: a[1],
            reduce_scatter_s: a[2],
            overlap_wait_s: a[3],
            optimizer_s: a[4],
        }
    }

    /// Sum of all phases (the accounted part of the step).
    pub fn total_s(&self) -> f64 {
        self.gather_s
            + self.compute_s
            + self.reduce_scatter_s
            + self.overlap_wait_s
            + self.optimizer_s
    }

    pub fn add(&mut self, other: &PhaseBreakdown) {
        self.gather_s += other.gather_s;
        self.compute_s += other.compute_s;
        self.reduce_scatter_s += other.reduce_scatter_s;
        self.overlap_wait_s += other.overlap_wait_s;
        self.optimizer_s += other.optimizer_s;
    }

    /// `(category, seconds)` pairs in timeline order.
    pub fn phases(&self) -> [(&'static str, f64); 5] {
        [
            (CAT_GATHER, self.gather_s),
            (CAT_OVERLAP_WAIT, self.overlap_wait_s),
            (CAT_COMPUTE, self.compute_s),
            (CAT_REDUCE_SCATTER, self.reduce_scatter_s),
            (CAT_OPTIMIZER, self.optimizer_s),
        ]
    }
}

/// Lay one rank's step phases onto the cross-rank timeline pid as
/// back-to-back spans starting at `start_us`. No-op while tracing is
/// off.
pub fn emit_rank_step(
    step: usize,
    rank: usize,
    start_us: f64,
    p: &PhaseBreakdown,
) {
    if !on() {
        return;
    }
    let mut at = start_us;
    for (cat, secs) in p.phases() {
        if secs <= 0.0 {
            continue;
        }
        let dur = secs * 1e6;
        complete_at(
            cat,
            &format!("step {step} {cat}"),
            PID_TIMELINE,
            rank as u64,
            at,
            dur,
        );
        at += dur;
    }
}

/// Per-lane fabric counters: relaxed atomics, always on (counting is
/// numerics-invisible and cheap), process-global — each worker
/// process snapshots its own.
pub struct FabricCounters {
    pub tcp_bytes_sent: AtomicU64,
    pub tcp_bytes_recv: AtomicU64,
    pub tcp_frames_sent: AtomicU64,
    pub tcp_frames_recv: AtomicU64,
    pub shm_bytes_sent: AtomicU64,
    pub shm_bytes_recv: AtomicU64,
    pub shm_frames_sent: AtomicU64,
    pub shm_frames_recv: AtomicU64,
    /// Hybrid routing decisions per edge class.
    pub hybrid_shm_routed: AtomicU64,
    pub hybrid_tcp_routed: AtomicU64,
    /// CRC-32 trailer mismatches (each one kills a lane).
    pub crc_failures: AtomicU64,
    /// Frames dropped by per-lane sequence dedup (duplicate injection,
    /// retransmits).
    pub seq_dedup_drops: AtomicU64,
    /// `resend_last` retransmissions put on the wire.
    pub resends: AtomicU64,
    pub heartbeats_sent: AtomicU64,
    pub heartbeats_recv: AtomicU64,
    /// Last / max liveness-probe (PING→PONG) round trip, microseconds.
    pub ping_rtt_us_last: AtomicU64,
    pub ping_rtt_us_max: AtomicU64,
    /// Liveness suspicions raised by the failure detector.
    pub suspicions: AtomicU64,
    /// Chaos faults fired (delay + dup + corrupt + crash).
    pub chaos_faults: AtomicU64,
}

impl FabricCounters {
    const fn new() -> FabricCounters {
        FabricCounters {
            tcp_bytes_sent: AtomicU64::new(0),
            tcp_bytes_recv: AtomicU64::new(0),
            tcp_frames_sent: AtomicU64::new(0),
            tcp_frames_recv: AtomicU64::new(0),
            shm_bytes_sent: AtomicU64::new(0),
            shm_bytes_recv: AtomicU64::new(0),
            shm_frames_sent: AtomicU64::new(0),
            shm_frames_recv: AtomicU64::new(0),
            hybrid_shm_routed: AtomicU64::new(0),
            hybrid_tcp_routed: AtomicU64::new(0),
            crc_failures: AtomicU64::new(0),
            seq_dedup_drops: AtomicU64::new(0),
            resends: AtomicU64::new(0),
            heartbeats_sent: AtomicU64::new(0),
            heartbeats_recv: AtomicU64::new(0),
            ping_rtt_us_last: AtomicU64::new(0),
            ping_rtt_us_max: AtomicU64::new(0),
            suspicions: AtomicU64::new(0),
            chaos_faults: AtomicU64::new(0),
        }
    }

    /// Record one liveness-probe round trip.
    pub fn record_ping_rtt(&self, us: u64) {
        self.ping_rtt_us_last.store(us, Ordering::Relaxed);
        self.ping_rtt_us_max.fetch_max(us, Ordering::Relaxed);
    }

    /// Name → value snapshot (deterministic order).
    pub fn snapshot(&self) -> BTreeMap<&'static str, u64> {
        let mut m = BTreeMap::new();
        let mut put = |k: &'static str, v: &AtomicU64| {
            m.insert(k, v.load(Ordering::Relaxed));
        };
        put("tcp_bytes_sent", &self.tcp_bytes_sent);
        put("tcp_bytes_recv", &self.tcp_bytes_recv);
        put("tcp_frames_sent", &self.tcp_frames_sent);
        put("tcp_frames_recv", &self.tcp_frames_recv);
        put("shm_bytes_sent", &self.shm_bytes_sent);
        put("shm_bytes_recv", &self.shm_bytes_recv);
        put("shm_frames_sent", &self.shm_frames_sent);
        put("shm_frames_recv", &self.shm_frames_recv);
        put("hybrid_shm_routed", &self.hybrid_shm_routed);
        put("hybrid_tcp_routed", &self.hybrid_tcp_routed);
        put("crc_failures", &self.crc_failures);
        put("seq_dedup_drops", &self.seq_dedup_drops);
        put("resends", &self.resends);
        put("heartbeats_sent", &self.heartbeats_sent);
        put("heartbeats_recv", &self.heartbeats_recv);
        put("ping_rtt_us_last", &self.ping_rtt_us_last);
        put("ping_rtt_us_max", &self.ping_rtt_us_max);
        put("suspicions", &self.suspicions);
        put("chaos_faults", &self.chaos_faults);
        m
    }

    /// The snapshot as a JSON object (trace metadata, session report).
    pub fn to_json(&self) -> Json {
        let m = self
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
            .collect();
        Json::Obj(m)
    }
}

static COUNTERS: FabricCounters = FabricCounters::new();

/// The process-global fabric counters.
pub fn counters() -> &'static FabricCounters {
    &COUNTERS
}

/// Per-rank trace path for spawned worker processes:
/// `trace.json` → `trace.rank3.json` (no extension: `trace.rank3`).
pub fn rank_trace_path(base: &str, rank: usize) -> String {
    match base.rsplit_once('.') {
        Some((stem, ext)) if !stem.is_empty() => {
            format!("{stem}.rank{rank}.{ext}")
        }
        _ => format!("{base}.rank{rank}"),
    }
}

/// Render every buffered event as Chrome trace-event JSON (the object
/// form Perfetto loads directly), with fabric counters and
/// `extra_metadata` attached, and write it to `path`. Consumes the
/// buffered events.
pub fn write_chrome_trace(
    path: &Path,
    extra_metadata: &[(&str, Json)],
) -> Result<()> {
    let events = take_events();
    let mut tracks: BTreeSet<(u32, u64)> = BTreeSet::new();
    for e in &events {
        tracks.insert((e.pid, e.tid));
    }
    let mut arr: Vec<Json> = Vec::with_capacity(events.len() + 8);
    for pid in [PID_LOCAL, PID_TIMELINE] {
        if tracks.iter().any(|&(p, _)| p == pid) {
            let label = if pid == PID_TIMELINE {
                "cross-rank step timeline"
            } else {
                "rank-local spans"
            };
            arr.push(meta_event("process_name", pid, 0, label));
        }
    }
    for &(pid, tid) in &tracks {
        arr.push(meta_event("thread_name", pid, tid, &format!("rank {tid}")));
    }
    for e in &events {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(e.name.clone()));
        o.insert("cat".into(), Json::Str(e.cat.to_string()));
        o.insert("pid".into(), Json::Num(e.pid as f64));
        o.insert("tid".into(), Json::Num(e.tid as f64));
        o.insert("ts".into(), Json::Num(e.ts_us));
        match e.dur_us {
            Some(d) => {
                o.insert("ph".into(), Json::Str("X".into()));
                o.insert("dur".into(), Json::Num(d));
            }
            None => {
                o.insert("ph".into(), Json::Str("i".into()));
                o.insert("s".into(), Json::Str("t".into()));
            }
        }
        arr.push(Json::Obj(o));
    }
    let mut meta = BTreeMap::new();
    meta.insert("fabric_counters".to_string(), counters().to_json());
    for (k, v) in extra_metadata {
        meta.insert(k.to_string(), v.clone());
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(arr));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".into()));
    root.insert("metadata".to_string(), Json::Obj(meta));
    std::fs::write(path, Json::Obj(root).render())
        .map_err(|e| anyhow!("writing trace to {}: {e}", path.display()))
}

fn meta_event(kind: &str, pid: u32, tid: u64, label: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(label.to_string()));
    let mut o = BTreeMap::new();
    o.insert("name".into(), Json::Str(kind.to_string()));
    o.insert("ph".into(), Json::Str("M".into()));
    o.insert("pid".into(), Json::Num(pid as f64));
    o.insert("tid".into(), Json::Num(tid as f64));
    o.insert("args".into(), Json::Obj(args));
    Json::Obj(o)
}

/// The tracer is process-global: tests anywhere in the crate that
/// enable/drain it (here and in `coordinator::app`) must serialize on
/// this lock or they steal each other's events.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static M: Mutex<()> = Mutex::new(());
    M.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        test_lock()
    }

    #[test]
    fn spans_are_inert_while_disabled() {
        let _g = lock();
        reset();
        {
            let s = span(CAT_GATHER, "quiet");
            assert!(s.inner.is_none());
        }
        instant(CAT_FAULT, "quiet");
        assert!(take_events().is_empty());
    }

    #[test]
    fn spans_and_instants_round_trip_with_rank_tids() {
        let _g = lock();
        reset();
        enable();
        set_rank(3);
        {
            let _outer = span(CAT_COMPUTE, "outer");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        instant(CAT_FAULT, "crash r3 s1");
        disable();
        let events = take_events();
        assert_eq!(events.len(), 2);
        let sp = events.iter().find(|e| e.dur_us.is_some()).unwrap();
        assert_eq!((sp.cat, sp.tid), (CAT_COMPUTE, 3));
        assert!(sp.dur_us.unwrap() >= 500.0, "slept ≥ 1ms: {sp:?}");
        let inst = events.iter().find(|e| e.dur_us.is_none()).unwrap();
        assert_eq!((inst.cat, inst.name.as_str()), (CAT_FAULT, "crash r3 s1"));
        set_rank(0);
    }

    #[test]
    fn chrome_trace_exports_parseable_nested_json() {
        let _g = lock();
        reset();
        enable();
        set_rank(1);
        {
            let _s = span(CAT_GATHER, "ag");
        }
        emit_rank_step(
            7,
            2,
            100.0,
            &PhaseBreakdown {
                gather_s: 1e-6,
                compute_s: 2e-6,
                reduce_scatter_s: 1e-6,
                overlap_wait_s: 0.0,
                optimizer_s: 1e-6,
            },
        );
        disable();
        let dir = std::env::temp_dir()
            .join(format!("cephalo-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.json");
        write_chrome_trace(&path, &[("backend", Json::Str("test".into()))])
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        let evs = j.field("traceEvents").unwrap().as_arr().unwrap();
        // Metadata events + the real span + 4 non-zero phases.
        let xs: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 5);
        // Timeline spans are back to back on pid 1, tid 2.
        let timeline: Vec<&&Json> = xs
            .iter()
            .filter(|e| e.get("pid").unwrap().as_f64() == Some(1.0))
            .collect();
        assert_eq!(timeline.len(), 4);
        assert!(timeline
            .iter()
            .all(|e| e.get("tid").unwrap().as_f64() == Some(2.0)));
        let meta = j.field("metadata").unwrap();
        assert!(meta.get("fabric_counters").is_some());
        assert_eq!(meta.get("backend").unwrap().as_str(), Some("test"));
        std::fs::remove_dir_all(&dir).ok();
        set_rank(0);
    }

    #[test]
    fn phase_breakdown_wire_array_round_trips() {
        let p = PhaseBreakdown {
            gather_s: 1.0,
            compute_s: 2.0,
            reduce_scatter_s: 3.0,
            overlap_wait_s: 4.0,
            optimizer_s: 5.0,
        };
        assert_eq!(PhaseBreakdown::from_array(p.to_array()), p);
        assert_eq!(p.total_s(), 15.0);
        let mut acc = PhaseBreakdown::default();
        acc.add(&p);
        acc.add(&p);
        assert_eq!(acc.gather_s, 2.0);
    }

    #[test]
    fn counters_snapshot_and_rtt() {
        counters().crc_failures.fetch_add(2, Ordering::Relaxed);
        counters().record_ping_rtt(120);
        counters().record_ping_rtt(80);
        let snap = counters().snapshot();
        assert!(snap["crc_failures"] >= 2);
        assert_eq!(snap["ping_rtt_us_last"], 80);
        assert!(snap["ping_rtt_us_max"] >= 120);
        let j = counters().to_json();
        assert!(j.get("tcp_bytes_sent").is_some());
    }

    #[test]
    fn rank_trace_paths_suffix_before_the_extension() {
        assert_eq!(rank_trace_path("trace.json", 2), "trace.rank2.json");
        assert_eq!(rank_trace_path("out/t.json", 1), "out/t.rank1.json");
        assert_eq!(rank_trace_path("trace", 3), "trace.rank3");
    }
}
