//! Numeric collectives over in-process ranks (§3.3 generalized
//! AllGather / ReduceScatter).
//!
//! The trainer's workers live in one address space, so a collective is a
//! deterministic transformation over per-rank buffers. Two
//! implementations are provided and property-tested against each other:
//!
//! * `direct_*` — the obvious gather/sum reference.
//! * `ring_*`  — a faithful segmented-ring schedule (what NCCL runs),
//!   operating in N-1 steps over the uneven shard layout. This is the
//!   implementation the trainer uses, so the tests double as evidence
//!   that uneven input sizes are handled exactly.
//!
//! All functions take a `ShardLayout` so even and uneven sharding share
//! one code path.

use crate::sharding::ShardLayout;

/// AllGather: each rank contributes its shard; returns the full vector.
/// Reference implementation: direct concatenation.
pub fn direct_allgather(shards: &[Vec<f32>], layout: &ShardLayout)
    -> Vec<f32> {
    assert_eq!(shards.len(), layout.num_ranks());
    let mut out = vec![0f32; layout.len()];
    for (rank, shard) in shards.iter().enumerate() {
        let range = layout.range(rank);
        assert_eq!(shard.len(), range.len(), "rank {rank} shard size");
        out[range].copy_from_slice(shard);
    }
    out
}

/// ReduceScatter: every rank holds a full-length contribution; rank r
/// receives the element-wise sum restricted to its shard range.
pub fn direct_reduce_scatter(full: &[Vec<f32>], layout: &ShardLayout)
    -> Vec<Vec<f32>> {
    let n = layout.num_ranks();
    assert_eq!(full.len(), n);
    for f in full {
        assert_eq!(f.len(), layout.len());
    }
    (0..n)
        .map(|rank| {
            let range = layout.range(rank);
            let mut shard = vec![0f32; range.len()];
            for contrib in full {
                for (o, v) in shard.iter_mut().zip(&contrib[range.clone()]) {
                    *o += v;
                }
            }
            shard
        })
        .collect()
}

/// AllReduce = ReduceScatter + AllGather (sum).
pub fn direct_allreduce(full: &[Vec<f32>], layout: &ShardLayout)
    -> Vec<f32> {
    let shards = direct_reduce_scatter(full, layout);
    direct_allgather(&shards, layout)
}

/// Segmented-ring AllGather: in step s, rank r forwards the segment it
/// received in step s-1 to rank (r+1) mod N; after N-1 steps everyone
/// holds all segments. Handles uneven (including empty) segments.
pub fn ring_allgather(shards: &[Vec<f32>], layout: &ShardLayout)
    -> Vec<f32> {
    let n = layout.num_ranks();
    assert_eq!(shards.len(), n);
    if n == 1 {
        // Guaranteed 1-rank fast path: the single shard IS the full
        // vector — no staging buffer, no ring bookkeeping, and exactly
        // the size assertion `direct_allgather` applies.
        assert_eq!(shards[0].len(), layout.len(), "rank 0 shard size");
        return shards[0].clone();
    }
    // Each rank's working buffer for the full vector.
    let mut bufs: Vec<Vec<f32>> = (0..n).map(|_| vec![0f32; layout.len()]).collect();
    for (rank, shard) in shards.iter().enumerate() {
        let range = layout.range(rank);
        assert_eq!(shard.len(), range.len());
        bufs[rank][range].copy_from_slice(shard);
    }
    // Ring steps: rank r sends segment (r - s) mod n in step s. A rank
    // whose turn lands on an empty segment (an `r_i = 0` shard) still
    // takes the step — it just forwards nothing, which is exactly what
    // NCCL does with zero-byte chunks.
    for s in 0..n.saturating_sub(1) {
        // Compute sends first (synchronous step semantics).
        let sends: Vec<(usize, usize, Vec<f32>)> = (0..n)
            .filter_map(|r| {
                let seg = (r + n - s) % n;
                let range = layout.range(seg);
                if range.is_empty() {
                    return None;
                }
                Some((r, seg, bufs[r][range].to_vec()))
            })
            .collect();
        for (r, seg, data) in sends {
            let dst = (r + 1) % n;
            let range = layout.range(seg);
            bufs[dst][range].copy_from_slice(&data);
        }
    }
    // All ranks now agree; return rank 0's view (asserted in tests).
    bufs.swap_remove(0)
}

/// Segmented-ring ReduceScatter: in step s, rank r sends the partial sum
/// of segment (r + 1 + s) mod n to rank r+1; after N-1 steps rank r
/// holds the full sum of its own segment.
pub fn ring_reduce_scatter(full: &[Vec<f32>], layout: &ShardLayout)
    -> Vec<Vec<f32>> {
    let n = layout.num_ranks();
    assert_eq!(full.len(), n);
    if n == 1 {
        // 1-rank fast path: the sum over one contribution is the
        // contribution itself, bit for bit (cloning preserves even
        // -0.0 payloads, which `direct_*`'s `0.0 + x` would not).
        assert_eq!(full[0].len(), layout.len(), "rank 0 contribution");
        return vec![full[0].clone()];
    }
    let mut bufs: Vec<Vec<f32>> = full.to_vec();
    for s in 0..n.saturating_sub(1) {
        // Rank r sends segment (r - s - 1 + 2n) mod n, accumulated into
        // the receiver's buffer. Empty segments (`r_i = 0` ranks) pass
        // through as zero-byte sends without touching any neighbor.
        let sends: Vec<(usize, usize, Vec<f32>)> = (0..n)
            .filter_map(|r| {
                let seg = (r + 2 * n - s - 1) % n;
                let range = layout.range(seg);
                if range.is_empty() {
                    return None;
                }
                Some((r, seg, bufs[r][range].to_vec()))
            })
            .collect();
        for (r, seg, data) in sends {
            let dst = (r + 1) % n;
            let range = layout.range(seg);
            for (o, v) in bufs[dst][range].iter_mut().zip(&data) {
                *o += v;
            }
        }
    }
    (0..n)
        .map(|r| bufs[r][layout.range(r)].to_vec())
        .collect()
}

/// Weighted sum across ranks without scatter — the Eq.-1 aggregation
/// used by the leader when shards carry per-GPU weights.
pub fn weighted_sum(full: &[Vec<f32>], weights: &[f32]) -> Vec<f32> {
    assert_eq!(full.len(), weights.len());
    assert!(!full.is_empty());
    let len = full[0].len();
    let mut out = vec![0f32; len];
    for (contrib, &w) in full.iter().zip(weights) {
        assert_eq!(contrib.len(), len);
        for (o, v) in out.iter_mut().zip(contrib) {
            *o += w * v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    fn gen_shards(g: &mut crate::testkit::Gen, layout: &ShardLayout)
        -> Vec<Vec<f32>> {
        (0..layout.num_ranks())
            .map(|r| g.vec_f32(layout.size(r), 2.0))
            .collect()
    }

    #[test]
    fn direct_allgather_even() {
        let layout = ShardLayout::even(6, 3);
        let shards = vec![vec![1., 2.], vec![3., 4.], vec![5., 6.]];
        assert_eq!(
            direct_allgather(&shards, &layout),
            vec![1., 2., 3., 4., 5., 6.]
        );
    }

    #[test]
    fn direct_reduce_scatter_sums() {
        let layout = ShardLayout::even(4, 2);
        let full = vec![vec![1., 1., 1., 1.], vec![2., 2., 2., 2.]];
        let shards = direct_reduce_scatter(&full, &layout);
        assert_eq!(shards, vec![vec![3., 3.], vec![3., 3.]]);
    }

    #[test]
    fn prop_ring_allgather_matches_direct() {
        check("ring-ag-vs-direct", 150, |g| {
            let n = g.usize_in(1, 9);
            let len = g.usize_in(0, 400);
            let ratios = g.ratios(n);
            let layout = if g.bool() {
                ShardLayout::even(len, n)
            } else {
                ShardLayout::by_ratios(len, &ratios)
            };
            let shards = gen_shards(g, &layout);
            let expect = direct_allgather(&shards, &layout);
            let got = ring_allgather(&shards, &layout);
            assert_eq!(got, expect);
        });
    }

    #[test]
    fn prop_ring_reduce_scatter_matches_direct() {
        check("ring-rs-vs-direct", 150, |g| {
            let n = g.usize_in(1, 9);
            let len = g.usize_in(0, 300);
            let ratios = g.ratios(n);
            let layout = if g.bool() {
                ShardLayout::even(len, n)
            } else {
                ShardLayout::by_ratios(len, &ratios)
            };
            let full: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_f32(len, 2.0)).collect();
            let expect = direct_reduce_scatter(&full, &layout);
            let got = ring_reduce_scatter(&full, &layout);
            for (rank, (e, r)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(e.len(), r.len());
                for (i, (a, b)) in e.iter().zip(r).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                        "rank {rank} elem {i}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_rs_then_ag_equals_allreduce() {
        // DESIGN.md invariant 4.
        check("rs-ag-is-allreduce", 100, |g| {
            let n = g.usize_in(1, 8);
            let len = g.usize_in(1, 200);
            let ratios = g.ratios(n);
            let layout = ShardLayout::by_ratios(len, &ratios);
            let full: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_f32(len, 1.0)).collect();
            let shards = ring_reduce_scatter(&full, &layout);
            let gathered = ring_allgather(&shards, &layout);
            let expect = direct_allreduce(&full, &layout);
            for (a, b) in gathered.iter().zip(&expect) {
                assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0));
            }
        });
    }

    #[test]
    fn prop_shard_roundtrip() {
        // DESIGN.md invariant 3: shard -> allgather is the identity.
        check("shard-roundtrip", 100, |g| {
            let n = g.usize_in(1, 8);
            let len = g.usize_in(0, 500);
            let ratios = g.ratios(n);
            let layout = ShardLayout::by_ratios(len, &ratios);
            let full = g.vec_f32(len, 3.0);
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|r| full[layout.range(r)].to_vec())
                .collect();
            assert_eq!(ring_allgather(&shards, &layout), full);
        });
    }

    #[test]
    fn weighted_sum_applies_weights() {
        let full = vec![vec![1., 2.], vec![10., 20.]];
        let out = weighted_sum(&full, &[1.0, 0.5]);
        assert_eq!(out, vec![6., 12.]);
    }

    #[test]
    fn empty_shard_ranks_are_fine() {
        // A GPU with r_i = 0 holds nothing but still participates.
        let layout = ShardLayout::by_ratios(8, &[1.0, 0.0, 1.0]);
        assert_eq!(layout.sizes(), vec![4, 0, 4]);
        let shards = vec![vec![1.; 4], vec![], vec![2.; 4]];
        let full = ring_allgather(&shards, &layout);
        assert_eq!(full.len(), 8);
        assert_eq!(&full[..4], &[1.; 4]);
        assert_eq!(&full[4..], &[2.; 4]);
    }

    #[test]
    fn single_survivor_layout_passes_through_the_ring() {
        // Degenerate elastic layout: ALL state on one rank, every other
        // rank `r_i = 0` — the N-1 ring steps must neither panic nor
        // corrupt neighbors, and sums must stay exact.
        let layout = ShardLayout::by_ratios(7, &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(layout.sizes(), vec![0, 7, 0, 0]);
        let owned: Vec<f32> = (1..=7).map(|x| x as f32).collect();
        let shards =
            vec![Vec::new(), owned.clone(), Vec::new(), Vec::new()];
        assert_eq!(ring_allgather(&shards, &layout), owned);
        let full: Vec<Vec<f32>> =
            (0..4).map(|r| vec![r as f32; 7]).collect();
        let rs = ring_reduce_scatter(&full, &layout);
        assert!(rs[0].is_empty() && rs[2].is_empty() && rs[3].is_empty());
        assert_eq!(rs[1], vec![6.0; 7]); // 0 + 1 + 2 + 3, exactly
    }

    #[test]
    fn prop_single_rank_ring_is_an_identity_fast_path() {
        // Satellite: the 1-rank ring is a guaranteed no-copy-loop fast
        // path, consistent with `direct_*` (which used to be only
        // accidentally true of the staging-buffer path), including the
        // zero-length and `sparse_ratios` corners.
        check("ring-single-rank-identity", 120, |g| {
            let len = g.usize_in(0, 400);
            let layout =
                ShardLayout::by_ratios(len, &g.sparse_ratios(1));
            assert_eq!(layout.num_ranks(), 1);
            let shard = g.vec_f32(len, 2.0);
            let ag = ring_allgather(&[shard.clone()], &layout);
            assert_eq!(ag, shard, "1-rank allgather must be identity");
            assert_eq!(ag, direct_allgather(&[shard.clone()], &layout));
            let rs = ring_reduce_scatter(&[shard.clone()], &layout);
            assert_eq!(rs.len(), 1);
            assert_eq!(rs[0], shard, "1-rank reduce-scatter is identity");
            assert_eq!(
                rs,
                direct_reduce_scatter(&[shard.clone()], &layout)
            );
        });
    }

    #[test]
    #[should_panic(expected = "rank 0 shard size")]
    fn single_rank_fast_path_keeps_direct_style_assertions() {
        // The fast path must reject malformed shards exactly like
        // `direct_allgather` does, not silently return them.
        let layout = ShardLayout::by_ratios(4, &[1.0]);
        let _ = ring_allgather(&[vec![1.0, 2.0]], &layout);
    }

    #[test]
    fn prop_ring_matches_direct_on_empty_shard_layouts() {
        // Satellite: the ring schedules against the direct reference
        // over layouts where random ranks hold r_i = 0 (including the
        // zero-length-vector corner).
        check("ring-vs-direct-empty-shards", 120, |g| {
            let n = g.usize_in(1, 8);
            let len = g.usize_in(0, 300);
            let layout =
                ShardLayout::by_ratios(len, &g.sparse_ratios(n));
            assert_eq!(layout.len(), len);

            let shards = gen_shards(g, &layout);
            assert_eq!(
                ring_allgather(&shards, &layout),
                direct_allgather(&shards, &layout),
            );

            let full: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_f32(len, 2.0)).collect();
            let expect = direct_reduce_scatter(&full, &layout);
            let got = ring_reduce_scatter(&full, &layout);
            for (rank, (e, r)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(e.len(), r.len(), "rank {rank} shard size");
                for (i, (a, b)) in e.iter().zip(r).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                        "rank {rank} elem {i}: {a} vs {b}"
                    );
                }
            }
        });
    }
}
