//! Cross-module integration: full profile -> optimize -> simulate
//! pipelines over every preset cluster and Table-2 model, baseline
//! planner robustness, and end-to-end property checks that span
//! optimizer + sharding + simulator.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{throughput, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::memory::usable_capacity;
use cephalo::model::table2_models;
use cephalo::optimizer::PlanError;
use cephalo::sim::GaVariant;
use cephalo::testkit::check;

#[test]
fn every_table2_model_plans_on_cluster_a_or_reports_oom_cleanly() {
    for model in table2_models() {
        let w = Workload::prepare(Cluster::cluster_a(), &model.name, 42)
            .expect("profile");
        match w.optimize(128) {
            Ok((asg, _)) => {
                assert_eq!(asg.global_batch(), 128, "{}", model.name);
                asg.validate(&w.profile, 128).unwrap();
                let stats = w.simulate(&asg, GaVariant::LGA_CO_S_O);
                assert!(stats.throughput > 0.0);
            }
            Err(PlanError::OutOfMemory { .. })
            | Err(PlanError::Infeasible(_)) => {
                // Only the 6.7B-class models may fail on 192 GB.
                assert!(
                    model.total_params() > 5_000_000_000,
                    "{} should fit on cluster A",
                    model.name
                );
            }
            Err(e) => panic!("{}: unexpected {e}", model.name),
        }
    }
}

#[test]
fn cluster_b_handles_the_7b_models() {
    for name in ["GPT 6.7B", "Llama 7B"] {
        let w = Workload::prepare(Cluster::cluster_b(), name, 42).unwrap();
        let (asg, _) = w.optimize(512).expect(name);
        asg.validate(&w.profile, 512).unwrap();
    }
}

#[test]
fn baselines_never_panic_across_the_matrix() {
    let systems = [
        SystemKind::MegatronHet,
        SystemKind::FlashFlex,
        SystemKind::Whale,
        SystemKind::Hap,
        SystemKind::Fsdp,
    ];
    for model in ["ViT-G", "BERT-Large", "GPT 2.7B", "Llama 3B"] {
        let w = Workload::prepare(Cluster::cluster_a(), model, 42).unwrap();
        for batch in [64usize, 128, 256] {
            for s in systems {
                // Result may be Ok or a clean planning error; panics are
                // the only failure.
                let _ = throughput(&w, batch, s);
            }
        }
    }
}

#[test]
fn simulated_memory_never_exceeds_physical_capacity() {
    // End-to-end invariant: for every feasible plan, the simulator's
    // per-GPU memory stays within the physical cards.
    for model in ["ViT-G", "BERT-Large", "GPT 2.7B"] {
        let w = Workload::prepare(Cluster::cluster_a(), model, 42).unwrap();
        for batch in [64usize, 128, 256] {
            let Ok((asg, _)) = w.optimize(batch) else { continue };
            let stats = w.simulate(&asg, GaVariant::LGA_CO_S_O);
            for (mem, slot) in stats.per_gpu_mem.iter().zip(w.cluster.gpus())
            {
                assert!(
                    *mem <= slot.spec.mem_bytes() * 1.001,
                    "{model} @{batch}: {} uses {:.1} GB > {:.1} GB",
                    slot.spec.name,
                    mem / 1e9,
                    slot.spec.mem_bytes() / 1e9
                );
            }
        }
    }
}

#[test]
fn prop_optimizer_feasible_over_random_batches() {
    let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
        .unwrap();
    check("optimizer-random-batches", 20, |g| {
        let batch = g.usize_in(8, 192);
        if let Ok((asg, _)) = w.optimize(batch) {
            assert_eq!(asg.global_batch(), batch);
            asg.validate(&w.profile, batch).unwrap();
            // State only on GPUs where it fits next to compute.
            for (gpu, m) in asg.per_gpu.iter().zip(&w.profile.per_gpu) {
                let compute = if gpu.microbatch > 0 {
                    m.mem.predict(gpu.microbatch)
                } else {
                    m.mem.intercept
                };
                let state = gpu.state_ratio
                    * cephalo::memory::state_bytes(w.profile.total_params);
                assert!(compute + state
                        <= usable_capacity(m.capacity) * 1.0001);
            }
        }
    });
}

#[test]
fn prop_more_memory_never_hurts() {
    // Upgrading every GPU's memory (same compute) must not reduce the
    // optimizer's predicted throughput.
    let base = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
        .unwrap();
    let mut big_cluster = Cluster::cluster_a();
    for node in big_cluster.nodes.iter_mut() {
        for gpu in node.gpus.iter_mut() {
            gpu.mem_gb *= 2.0;
        }
    }
    let big = Workload::prepare(big_cluster, "GPT 2.7B", 42).unwrap();
    for batch in [64usize, 128] {
        let t_base = base
            .optimize(batch)
            .map(|(a, _)| a.throughput())
            .unwrap_or(0.0);
        let t_big = big
            .optimize(batch)
            .map(|(a, _)| a.throughput())
            .unwrap_or(0.0);
        assert!(
            t_big >= t_base * 0.999,
            "doubling memory reduced throughput @{batch}: {t_base} -> \
             {t_big}"
        );
    }
}

#[test]
fn ga_variant_ladder_monotone_on_random_workloads() {
    use cephalo::sim::{simulate_iteration, FsdpWorkload};
    check("ladder-monotone", 30, |g| {
        let n = g.usize_in(2, 6);
        let units = g.usize_in(2, 12);
        let l = g.usize_in(2, 8);
        let w = FsdpWorkload {
            units,
            micro: vec![(g.usize_in(1, 4), l); n],
            fwd_micro: (0..n).map(|_| g.f64_in(0.001, 0.05)).collect(),
            bwd_micro: (0..n).map(|_| g.f64_in(0.003, 0.15)).collect(),
            ag_unit: (0..units).map(|_| g.f64_in(0.001, 0.08)).collect(),
            rs_unit: (0..units).map(|_| g.f64_in(0.001, 0.08)).collect(),
            offload_micro: (0..n).map(|_| g.f64_in(0.0001, 0.002)).collect(),
        };
        let fsdp_ga = simulate_iteration(&w, GaVariant::FSDP_GA).latency;
        let lga = simulate_iteration(&w, GaVariant::LGA).latency;
        let lga_co = simulate_iteration(&w, GaVariant::LGA_CO).latency;
        let full = simulate_iteration(&w, GaVariant::LGA_CO_S_O).latency;
        assert!(lga <= fsdp_ga * 1.001, "LGA worse than FSDP-GA");
        assert!(lga_co <= lga * 1.001, "CO hurt");
        assert!(full <= lga_co * 1.02, "S+O hurt: {full} vs {lga_co}");
    });
}

#[test]
fn config_file_cluster_roundtrip() {
    let toml = r#"
[cluster]
name = "ci"
inter_bw_gbps = 40.0

[[node]]
gpus = ["A10G", "A10G", "T4", "T4"]
intra_bw_gbps = 96.0

[[node]]
gpus = ["V100", "V100", "V100", "V100"]
intra_bw_gbps = 300.0
"#;
    let cfg = cephalo::configfmt::Config::parse(toml).unwrap();
    let cluster = Cluster::from_config(&cfg).unwrap();
    assert_eq!(cluster.num_gpus(), 8);
    let w = Workload::prepare(cluster, "BERT-Large", 1).unwrap();
    let (asg, _) = w.optimize(64).unwrap();
    assert_eq!(asg.global_batch(), 64);
}
