//! Acceptance (tentpole): a 3-rank TCP-loopback training run — real
//! sockets, real rendezvous, real wire collectives — of ≥ 3 steps
//! including ≥ 1 elastic re-plan with state migration over the
//! transport, produces BITWISE-identical parameters to (a) the same
//! session over in-process channels (`LocalTransport`), (b) the
//! historical in-process trainer, and (c) a single-worker reference —
//! all in the default (no-`xla`) build.
//!
//! This is DESIGN.md invariant 10 ("the wire is bitwise-invisible") at
//! full system scope: planner registry + plan cache + migration
//! transfer lists + SPMD wire training, three substrates, one
//! trajectory.

use std::sync::Arc;

use cephalo::coordinator::session::{Session, SessionConfig};
use cephalo::exec::{NativeExecutor, SurrogateSpec};
use cephalo::plan::CephaloPlanner;
use cephalo::testkit::tiny_cluster3;
use cephalo::trainer::{TrainConfig, Trainer, WorkerSpec};
use cephalo::transport::FabricSpec;

const SEED: u64 = 13;
const BATCH: usize = 8;
const STEPS_PER_EVENT: usize = 2;

fn session(fabric: Option<FabricSpec>) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric,
        ..Default::default()
    };
    Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("session starts on the 3-GPU cluster")
}

fn reference() -> Trainer {
    // One worker, the whole batch, the whole state — same surrogate,
    // seed and corpus stream as every session engine.
    let cfg = TrainConfig {
        steps: 0,
        seed: SEED,
        log_every: 0,
        ..Default::default()
    };
    Trainer::from_executor(
        Box::new(NativeExecutor::new(SurrogateSpec::default())),
        vec![WorkerSpec {
            batch: BATCH,
            state_ratio: 1.0,
            name: "solo".into(),
        }],
        cfg,
    )
    .unwrap()
}

#[test]
fn tcp_session_is_bitwise_identical_to_local_inprocess_and_reference() {
    let mut tcp = session(Some(FabricSpec::TcpThreads));
    let mut local = session(Some(FabricSpec::Local));
    let mut inproc = session(None);
    let mut reference = reference();

    assert_eq!(tcp.backend_label(), "native+tcp");
    assert_eq!(local.backend_label(), "native+local");
    assert_eq!(
        tcp.params(),
        reference.params(),
        "same seed must give the same init on every substrate"
    );
    assert_eq!(local.params(), reference.params());
    assert_eq!(inproc.params(), reference.params());

    // Explicit churn: 3 -> 2 (shrink: the departed rank's Adam shard
    // moves over the wire) -> 3 (regrow: the rejoining rank receives
    // params + state ranges) -> 2 again (the recurring membership must
    // be a plan-cache hit).
    let churn = [2usize, 3, 2];
    for (hour, &size) in churn.iter().enumerate() {
        let rt = tcp.step_event(hour, size).unwrap();
        let rl = local.step_event(hour, size).unwrap();
        let ri = inproc.step_event(hour, size).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = reference.history.len();
            reference.step(idx).unwrap();
        }
        assert_eq!(rt.gpus, size);
        assert_eq!(
            tcp.params(),
            inproc.params(),
            "tcp diverged from in-process after event {hour} \
             (membership {size})"
        );
        assert_eq!(
            local.params(),
            inproc.params(),
            "local diverged from in-process after event {hour}"
        );
        assert_eq!(
            inproc.params(),
            reference.params(),
            "in-process diverged from the single-worker reference \
             after event {hour}"
        );
        // All three engines executed the SAME migration volume.
        assert_eq!(rt.moved_state_elems, ri.moved_state_elems);
        assert_eq!(rl.moved_state_elems, ri.moved_state_elems);
        // Losses ride the same trajectory (worker count changes the
        // f64 reduction grouping, so compare approximately).
        assert!(
            (rt.mean_loss - ri.mean_loss).abs()
                <= 1e-9 * ri.mean_loss.abs().max(1.0),
            "loss diverged: tcp {} vs inproc {}",
            rt.mean_loss,
            ri.mean_loss
        );
    }

    // ≥ 3 steps ran, and at least one event really moved state.
    assert!(tcp.steps_run() >= 3);
    assert_eq!(tcp.steps_run(), churn.len() * STEPS_PER_EVENT);
    let moved: usize =
        tcp.reports.iter().map(|r| r.moved_state_elems).sum();
    assert!(moved > 0, "churn never moved any state over the wire");

    // Recurring memberships are cache hits, not DP solves.
    assert!(
        tcp.cache().hits() >= 1,
        "returning to a seen membership must hit the plan cache"
    );
    assert!(tcp.reports.iter().any(|r| r.from_cache));
}

#[test]
fn trace_driven_tcp_session_matches_the_inprocess_session() {
    // Same invariant with membership sizes from the AWS availability
    // trace — the actual `elastic --live --transport tcp` path.
    let mut tcp = session(Some(FabricSpec::TcpThreads));
    let mut inproc = session(None);
    let sizes = tcp.churn_sizes(3);
    assert!(sizes.len() >= 3);
    for (hour, &size) in sizes.iter().enumerate() {
        tcp.step_event(hour, size).unwrap();
        inproc.step_event(hour, size).unwrap();
        assert_eq!(
            tcp.params(),
            inproc.params(),
            "diverged after trace hour {hour} (size {size})"
        );
    }
}
