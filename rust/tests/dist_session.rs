//! Acceptance (tentpole): a 3-rank TCP-loopback training run — real
//! sockets, real rendezvous, real wire collectives — of ≥ 3 steps
//! including ≥ 1 elastic re-plan with state migration over the
//! transport, produces BITWISE-identical parameters to (a) the same
//! session over in-process channels (`LocalTransport`), (b) the
//! historical in-process trainer, and (c) a single-worker reference —
//! all in the default (no-`xla`) build.
//!
//! This is DESIGN.md invariant 10 ("the wire is bitwise-invisible") at
//! full system scope: planner registry + plan cache + migration
//! transfer lists + SPMD wire training, three substrates, one
//! trajectory. The fully-sharded tests extend it to invariant 11: with
//! `shard_params`, NO rank holds a leader-resident weight copy, the
//! weights migrate over the wire alongside the Adam moments, and the
//! trajectory still matches the leader-resident reference bit for bit
//! across churn on every transport.

//! PR 6 extends the scope to fail-stop faults: a chaos-injected crash
//! on the socket fabric is detected by the liveness poll, re-planned,
//! and its state re-streamed from the rank-0 mirror — and the session
//! STILL rides the single-worker reference trajectory bit for bit
//! (DESIGN.md invariant 12: crash recovery ≡ graceful departure).
//!
//! The rejoin round adds DESIGN.md invariant 15, both halves: a
//! partitioned-then-returned rank re-admitted through the REJOIN
//! handshake is bitwise-equivalent to a departure + arrival (in place
//! on a fingerprint hit, re-streamed on a miss), and recovery from the
//! default sharded mirror is bitwise-equivalent to recovery from the
//! legacy rank-0 flat mirror — asserted under seeded coordinator-side
//! chaos on the TCP and hybrid fabrics, across churn.

use std::sync::Arc;

use cephalo::coordinator::session::{Session, SessionConfig};
use cephalo::exec::{NativeExecutor, SurrogateSpec};
use cephalo::plan::CephaloPlanner;
use cephalo::testkit::tiny_cluster3;
use cephalo::trainer::{TrainConfig, Trainer, WorkerSpec};
use cephalo::transport::FabricSpec;

const SEED: u64 = 13;
const BATCH: usize = 8;
const STEPS_PER_EVENT: usize = 2;

fn session_with(fabric: Option<FabricSpec>, shard_params: bool) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric,
        shard_params,
        ..Default::default()
    };
    Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("session starts on the 3-GPU cluster")
}

fn session(fabric: Option<FabricSpec>) -> Session {
    session_with(fabric, false)
}

/// A fully-sharded session with the gather cut into `fsdp_units`
/// per-layer units (prefetch overlap + per-unit free).
fn session_units(fabric: Option<FabricSpec>, fsdp_units: usize) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric,
        shard_params: true,
        fsdp_units,
        ..Default::default()
    };
    Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("session starts on the 3-GPU cluster")
}

/// A 5-GPU single-node cluster: enough worker ranks to absorb three
/// injected crashes (ranks 4, 3, 2) and still hold a 2-rank quorum.
fn tiny5_cluster() -> cephalo::cluster::Cluster {
    use cephalo::cluster::catalog::find;
    use cephalo::cluster::{Cluster, Node};
    Cluster {
        name: "tiny5".into(),
        nodes: vec![Node {
            name: "n0".into(),
            gpus: vec![
                find("T4").unwrap(),
                find("V100").unwrap(),
                find("P40").unwrap(),
                find("P100").unwrap(),
                find("L4").unwrap(),
            ],
            intra_bw_gbps: 64.0,
        }],
        inter_bw_gbps: 50.0,
    }
}

/// A session on the 5-GPU cluster, optionally under a chaos schedule.
fn session5(
    fabric: Option<FabricSpec>,
    shard_params: bool,
    chaos: Option<&str>,
) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric,
        shard_params,
        chaos: chaos.map(String::from),
        ..Default::default()
    };
    Session::new(
        tiny5_cluster(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("session starts on the 5-GPU cluster")
}

/// A hybrid-fabric session on the 3-GPU cluster under `hosts`.
fn session_hybrid(hosts: Vec<u64>, shard_params: bool) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric: Some(FabricSpec::HybridThreads),
        shard_params,
        hosts: Some(hosts),
        ..Default::default()
    };
    Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("hybrid session starts on the 3-GPU cluster")
}

/// A hybrid-fabric session on the 5-GPU cluster, optionally chaotic.
fn session5_hybrid(
    hosts: Vec<u64>,
    shard_params: bool,
    chaos: Option<&str>,
) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric: Some(FabricSpec::HybridThreads),
        shard_params,
        hosts: Some(hosts),
        chaos: chaos.map(String::from),
        ..Default::default()
    };
    Session::new(
        tiny5_cluster(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("hybrid session starts on the 5-GPU cluster")
}

fn reference() -> Trainer {
    // One worker, the whole batch, the whole state — same surrogate,
    // seed and corpus stream as every session engine.
    let cfg = TrainConfig {
        steps: 0,
        seed: SEED,
        log_every: 0,
        ..Default::default()
    };
    Trainer::from_executor(
        Box::new(NativeExecutor::new(SurrogateSpec::default())),
        vec![WorkerSpec {
            batch: BATCH,
            state_ratio: 1.0,
            name: "solo".into(),
        }],
        cfg,
    )
    .unwrap()
}

#[test]
fn tcp_session_is_bitwise_identical_to_local_inprocess_and_reference() {
    let mut tcp = session(Some(FabricSpec::TcpThreads));
    let mut local = session(Some(FabricSpec::Local));
    let mut inproc = session(None);
    let mut reference = reference();

    assert_eq!(tcp.backend_label(), "native+tcp");
    assert_eq!(local.backend_label(), "native+local");
    assert_eq!(
        tcp.params().unwrap(),
        reference.params(),
        "same seed must give the same init on every substrate"
    );
    assert_eq!(local.params().unwrap(), reference.params());
    assert_eq!(inproc.params().unwrap(), reference.params());

    // Explicit churn: 3 -> 2 (shrink: the departed rank's Adam shard
    // moves over the wire) -> 3 (regrow: the rejoining rank receives
    // params + state ranges) -> 2 again (the recurring membership must
    // be a plan-cache hit).
    let churn = [2usize, 3, 2];
    for (hour, &size) in churn.iter().enumerate() {
        let rt = tcp.step_event(hour, size).unwrap();
        let rl = local.step_event(hour, size).unwrap();
        let ri = inproc.step_event(hour, size).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = reference.history.len();
            reference.step(idx).unwrap();
        }
        assert_eq!(rt.gpus, size);
        assert_eq!(
            tcp.params().unwrap(),
            inproc.params().unwrap(),
            "tcp diverged from in-process after event {hour} \
             (membership {size})"
        );
        assert_eq!(
            local.params().unwrap(),
            inproc.params().unwrap(),
            "local diverged from in-process after event {hour}"
        );
        assert_eq!(
            inproc.params().unwrap(),
            reference.params(),
            "in-process diverged from the single-worker reference \
             after event {hour}"
        );
        // All three engines executed the SAME migration volume.
        assert_eq!(rt.moved_state_elems, ri.moved_state_elems);
        assert_eq!(rl.moved_state_elems, ri.moved_state_elems);
        // Losses ride the same trajectory (worker count changes the
        // f64 reduction grouping, so compare approximately).
        assert!(
            (rt.mean_loss - ri.mean_loss).abs()
                <= 1e-9 * ri.mean_loss.abs().max(1.0),
            "loss diverged: tcp {} vs inproc {}",
            rt.mean_loss,
            ri.mean_loss
        );
    }

    // ≥ 3 steps ran, and at least one event really moved state.
    assert!(tcp.steps_run() >= 3);
    assert_eq!(tcp.steps_run(), churn.len() * STEPS_PER_EVENT);
    let moved: usize =
        tcp.reports.iter().map(|r| r.moved_state_elems).sum();
    assert!(moved > 0, "churn never moved any state over the wire");

    // Recurring memberships are cache hits, not DP solves.
    assert!(
        tcp.cache().hits() >= 1,
        "returning to a seen membership must hit the plan cache"
    );
    assert!(tcp.reports.iter().any(|r| r.from_cache));
}

#[test]
fn trace_driven_tcp_session_matches_the_inprocess_session() {
    // Same invariant with membership sizes from the AWS availability
    // trace — the actual `elastic --live --transport tcp` path.
    let mut tcp = session(Some(FabricSpec::TcpThreads));
    let mut inproc = session(None);
    let sizes = tcp.churn_sizes(3);
    assert!(sizes.len() >= 3);
    for (hour, &size) in sizes.iter().enumerate() {
        tcp.step_event(hour, size).unwrap();
        inproc.step_event(hour, size).unwrap();
        assert_eq!(
            tcp.params().unwrap(),
            inproc.params().unwrap(),
            "diverged after trace hour {hour} (size {size})"
        );
    }
}

#[test]
fn fully_sharded_sessions_match_the_leader_resident_reference() {
    // Acceptance (tentpole, invariant 11): fully-sharded sessions on
    // ALL THREE substrates — in-process, channel fabric, TCP-loopback
    // sockets — ride the leader-resident reference trajectory bit for
    // bit across ≥ 3 churn events, with weight ranges migrating
    // alongside the Adam moments (and re-streamed by standby ranks
    // for departed owners). No engine holds a leader copy: params()
    // is an explicit export (COLLECT over the wire).
    let mut sh_tcp = session_with(Some(FabricSpec::TcpThreads), true);
    let mut sh_local = session_with(Some(FabricSpec::Local), true);
    let mut sh_inproc = session_with(None, true);
    let mut leader = session(None); // the leader-resident reference
    let mut solo = reference();

    assert!(sh_inproc.trainer().is_sharded());
    assert!(!leader.trainer().is_sharded());
    assert_eq!(sh_tcp.params().unwrap(), solo.params());
    assert_eq!(sh_local.params().unwrap(), solo.params());
    assert_eq!(sh_inproc.params().unwrap(), solo.params());

    // Per-rank resident weight bytes scale with r_i (the in-process
    // engine exposes the measured shards directly).
    let pb = sh_inproc.trainer().param_bytes_per_worker();
    let total: usize = pb.iter().sum();
    assert_eq!(total, sh_inproc.trainer().num_params() * 4);
    assert!(
        pb.iter().any(|&b| b < total),
        "no single rank may hold the full weight copy: {pb:?}"
    );

    // ≥ 3 churn events: shrink (weights of the departed rank stream
    // over the wire), regrow (the rejoining rank's slice is rebuilt
    // from transfers alone — no full-param stream exists), recur
    // (cache hit).
    let churn = [2usize, 3, 2];
    for (hour, &size) in churn.iter().enumerate() {
        let rt = sh_tcp.step_event(hour, size).unwrap();
        let rl = sh_local.step_event(hour, size).unwrap();
        let ri = sh_inproc.step_event(hour, size).unwrap();
        let rd = leader.step_event(hour, size).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = solo.history.len();
            solo.step(idx).unwrap();
        }
        assert_eq!(
            sh_tcp.params().unwrap(),
            solo.params(),
            "sharded tcp diverged after event {hour} (size {size})"
        );
        assert_eq!(
            sh_local.params().unwrap(),
            solo.params(),
            "sharded local diverged after event {hour}"
        );
        assert_eq!(
            sh_inproc.params().unwrap(),
            solo.params(),
            "sharded in-process diverged after event {hour}"
        );
        assert_eq!(
            leader.params().unwrap(),
            solo.params(),
            "leader-resident reference diverged after event {hour}"
        );
        // Sharded and leader-resident engines plan the SAME migration
        // volume — the transfer list is residency-independent.
        assert_eq!(rt.moved_state_elems, rd.moved_state_elems);
        assert_eq!(rl.moved_state_elems, rd.moved_state_elems);
        assert_eq!(ri.moved_state_elems, rd.moved_state_elems);
    }
    let moved: usize =
        sh_tcp.reports.iter().map(|r| r.moved_state_elems).sum();
    assert!(moved > 0, "churn never moved any sharded weights");
    assert!(sh_tcp.reports.iter().any(|r| r.from_cache));
    assert_eq!(sh_tcp.steps_run(), churn.len() * STEPS_PER_EVENT);
}

#[test]
fn unit_sharded_sessions_match_the_whole_gather_reference() {
    // Acceptance (tentpole, invariant 13): cutting the per-step gather
    // into per-layer FSDP units — AllGather unit k+1 in the background
    // while unit k computes, free each unit after its ReduceScatter —
    // changes WHEN parameters are materialized, not one bit of the
    // trajectory. Unit-sharded sessions on all three substrates ride
    // the whole-gather and single-worker reference trajectories bit
    // for bit across ≥ 3 churn events (≥ 2 migrations), while the
    // transient parameter peak drops from the full flat length to the
    // double-buffered unit pair plus the tail.
    let mut u_tcp = session_units(Some(FabricSpec::TcpThreads), 4);
    let mut u_local = session_units(Some(FabricSpec::Local), 4);
    let mut u_inproc = session_units(None, 4);
    let mut whole = session_with(None, true); // whole-model gather
    let mut solo = reference();

    // The in-process engine really runs the unit pipeline; the
    // whole-gather reference really does not.
    assert!(u_inproc.trainer().units().num_units() > 1);
    assert_eq!(whole.trainer().units().num_units(), 1);
    assert_eq!(u_tcp.params().unwrap(), solo.params());
    assert_eq!(u_local.params().unwrap(), solo.params());
    assert_eq!(u_inproc.params().unwrap(), solo.params());

    // Shrink (unit slices of the departed rank stream over the wire),
    // regrow, recur (plan-cache hit) — the unit plan is rebuilt on
    // every membership change.
    let churn = [2usize, 3, 2];
    for (hour, &size) in churn.iter().enumerate() {
        let rt = u_tcp.step_event(hour, size).unwrap();
        let rl = u_local.step_event(hour, size).unwrap();
        let ri = u_inproc.step_event(hour, size).unwrap();
        let rw = whole.step_event(hour, size).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = solo.history.len();
            solo.step(idx).unwrap();
        }
        assert_eq!(
            u_tcp.params().unwrap(),
            solo.params(),
            "unit-sharded tcp diverged after event {hour} (size {size})"
        );
        assert_eq!(
            u_local.params().unwrap(),
            solo.params(),
            "unit-sharded local diverged after event {hour}"
        );
        assert_eq!(
            u_inproc.params().unwrap(),
            solo.params(),
            "unit-sharded in-process diverged after event {hour}"
        );
        assert_eq!(
            whole.params().unwrap(),
            solo.params(),
            "whole-gather reference diverged after event {hour}"
        );
        // The unit grouping is invisible to the migration planner:
        // every engine moves the SAME state volume.
        assert_eq!(rt.moved_state_elems, rw.moved_state_elems);
        assert_eq!(rl.moved_state_elems, rw.moved_state_elems);
        assert_eq!(ri.moved_state_elems, rw.moved_state_elems);
    }

    // Transient parameter peak: the whole-gather engine materialized
    // every element; the unit engine held at most two table units
    // (current + prefetched) plus the tail.
    let flat = u_inproc.trainer().num_params();
    assert_eq!(whole.trainer().peak_materialized_elems(), flat);
    let ul = u_inproc.trainer().units();
    let tail_len = ul.unit_len(ul.num_units() - 1);
    let peak = u_inproc.trainer().peak_materialized_elems();
    assert!(peak > 0, "unit engine never materialized anything");
    assert!(
        peak <= 2 * ul.largest_unit() + tail_len,
        "unit peak {peak} exceeds two units + tail \
         ({} + {tail_len})",
        2 * ul.largest_unit()
    );
    assert!(peak < flat, "unit peak must undercut the whole gather");

    let moved: usize =
        u_tcp.reports.iter().map(|r| r.moved_state_elems).sum();
    assert!(moved > 0, "churn never moved any unit-sharded weights");
    assert!(u_tcp.reports.iter().any(|r| r.from_cache));
    assert_eq!(u_tcp.steps_run(), churn.len() * STEPS_PER_EVENT);
}

#[test]
fn shm_and_hybrid_sessions_match_the_reference_across_churn() {
    // Tentpole acceptance (invariant 10, locality fabrics): the mmap
    // ring fabric and the locality-routed hybrid fabric (ranks 0 and 2
    // share a host; rank 1 is remote, so its hops ride the channel
    // lane while 0<->2 rides shm) run the SAME churn as the tcp test —
    // shrink, regrow, recur — and never leave the single-worker
    // reference trajectory, leader-resident and fully-sharded.
    for shard_params in [false, true] {
        let mut shm =
            session_with(Some(FabricSpec::ShmThreads), shard_params);
        let mut hybrid = session_hybrid(vec![0, 1, 0], shard_params);
        let mut inproc = session_with(None, shard_params);
        let mut solo = reference();

        assert_eq!(shm.backend_label(), "native+shm");
        assert_eq!(hybrid.backend_label(), "native+hybrid");
        assert_eq!(shm.params().unwrap(), solo.params());
        assert_eq!(hybrid.params().unwrap(), solo.params());

        let churn = [2usize, 3, 2];
        for (hour, &size) in churn.iter().enumerate() {
            let rs = shm.step_event(hour, size).unwrap();
            let rh = hybrid.step_event(hour, size).unwrap();
            let ri = inproc.step_event(hour, size).unwrap();
            for _ in 0..STEPS_PER_EVENT {
                let idx = solo.history.len();
                solo.step(idx).unwrap();
            }
            assert_eq!(
                shm.params().unwrap(),
                solo.params(),
                "shm session diverged after event {hour} (size {size}, \
                 shard_params={shard_params})"
            );
            assert_eq!(
                hybrid.params().unwrap(),
                solo.params(),
                "hybrid session diverged after event {hour} \
                 (size {size}, shard_params={shard_params})"
            );
            // The lane split is invisible to the migration planner.
            assert_eq!(rs.moved_state_elems, ri.moved_state_elems);
            assert_eq!(rh.moved_state_elems, ri.moved_state_elems);
        }
        let moved: usize =
            hybrid.reports.iter().map(|r| r.moved_state_elems).sum();
        assert!(moved > 0, "churn never moved state over the fabrics");
        assert!(hybrid.reports.iter().any(|r| r.from_cache));
    }
}

#[test]
fn chaotic_hybrid_session_survives_crashes_bitwise() {
    // Invariant 12 over the locality fabric: a chaos-injected crash on
    // a two-host hybrid mesh (the victim shares a host with a
    // survivor, so its shm lanes die WITH its channel lanes) is
    // detected, re-planned and mirror-restored — and the session still
    // rides the reference trajectory bit for bit, ending equal to a
    // fault-free run.
    for shard_params in [false, true] {
        let mut chaotic = session5_hybrid(
            vec![0, 0, 0, 1, 1],
            shard_params,
            Some("seed=3,crash=1,first=1,stride=2,delay=0,dup=0"),
        );
        let mut graceful = session5(None, shard_params, None);
        let mut solo = reference();
        assert!(chaotic.fault_plan().is_some());
        assert_eq!(chaotic.params().unwrap(), solo.params());

        let events = 3;
        for hour in 0..events {
            chaotic.step_event(hour, 5).unwrap();
            graceful.step_event(hour, 5).unwrap();
            for _ in 0..STEPS_PER_EVENT {
                let idx = solo.history.len();
                solo.step(idx).unwrap();
            }
            assert_eq!(
                chaotic.params().unwrap(),
                solo.params(),
                "chaotic hybrid session left the reference trajectory \
                 after hour {hour} (shard_params={shard_params})"
            );
        }
        assert_eq!(
            chaotic.recoveries.len(),
            1,
            "expected one recovery for the scheduled crash \
             (shard_params={shard_params}): {:?}",
            chaotic.recoveries
        );
        assert_eq!(chaotic.recoveries[0].ranks, vec![4]);
        assert_eq!(chaotic.steps_run(), graceful.steps_run());
        assert_eq!(
            chaotic.params().unwrap(),
            graceful.params().unwrap(),
            "hybrid crash recovery diverged from the fault-free \
             session (shard_params={shard_params})"
        );
    }
}

#[test]
fn chaotic_tcp_sessions_survive_three_crashes_bitwise() {
    // Acceptance (tentpole): three injected worker crashes on the real
    // socket fabric, leader-resident AND fully-sharded. Every crash is
    // detected by the liveness poll, the membership is re-planned, and
    // the dead rank's Adam state (and weight slice, when sharded) is
    // re-streamed from the rank-0 mirror over the wire. The session
    // never leaves the single-worker reference trajectory, and ends
    // bitwise equal to a session that never saw a fault — DESIGN.md
    // invariant 12 at full system scope.
    for shard_params in [false, true] {
        let mut chaotic = session5(
            Some(FabricSpec::TcpThreads),
            shard_params,
            Some("seed=3,crash=3,first=1,stride=2,delay=0,dup=0"),
        );
        let mut graceful = session5(None, shard_params, None);
        let mut solo = reference();
        assert!(chaotic.fault_plan().is_some());
        assert_eq!(chaotic.params().unwrap(), solo.params());

        // Crash steps: rank 4 after step 1, then ranks 3 and 2 at
        // stride-2 spacing plus jitter — the last lands by step 9, so
        // 7 events (14 steps) cover every detection with margin.
        let events = 7;
        for hour in 0..events {
            chaotic.step_event(hour, 5).unwrap();
            graceful.step_event(hour, 5).unwrap();
            for _ in 0..STEPS_PER_EVENT {
                let idx = solo.history.len();
                solo.step(idx).unwrap();
            }
            assert_eq!(
                chaotic.params().unwrap(),
                solo.params(),
                "chaotic session left the reference trajectory after \
                 hour {hour} (shard_params={shard_params})"
            );
        }

        // All three scheduled crashes were detected, one poll each,
        // shrinking the membership 4 -> 3 -> 2.
        assert_eq!(
            chaotic.recoveries.len(),
            3,
            "expected one recovery per scheduled crash \
             (shard_params={shard_params}): {:?}",
            chaotic.recoveries
        );
        let mut dead: Vec<usize> = chaotic
            .recoveries
            .iter()
            .flat_map(|r| r.ranks.clone())
            .collect();
        dead.sort_unstable();
        assert_eq!(dead, vec![2, 3, 4]);
        assert_eq!(
            chaotic.recoveries.iter().map(|r| r.gpus).collect::<Vec<_>>(),
            vec![4, 3, 2]
        );
        assert_eq!(chaotic.max_live(), 2);
        assert_eq!(chaotic.current_size(), 2);

        // Invariant 12: the crash-recovered session is bitwise equal
        // to the fault-free session (membership is invisible, so the
        // graceful run's intact 5-rank group rides the same path).
        assert_eq!(chaotic.steps_run(), graceful.steps_run());
        assert_eq!(
            chaotic.params().unwrap(),
            graceful.params().unwrap(),
            "crash recovery diverged from the fault-free session \
             (shard_params={shard_params})"
        );
    }
}

#[test]
fn tracing_is_bitwise_invisible_under_churn_and_chaos() {
    // DESIGN.md invariant 14: spans and counters OBSERVE the step,
    // they never participate in it. A session traced end to end, one
    // traced for part of its life (toggled between events), and one
    // never traced produce bitwise-identical parameters — across
    // churn AND a chaos-injected crash. The runs are sequential
    // because the tracer is process-global.
    use cephalo::telemetry;

    let run_churn = |policy: fn(usize)| {
        let mut s = session_with(Some(FabricSpec::TcpThreads), true);
        let churn = [2usize, 3, 2];
        for (hour, &size) in churn.iter().enumerate() {
            policy(hour);
            s.step_event(hour, size).unwrap();
        }
        telemetry::reset();
        s.params().unwrap()
    };
    let off = run_churn(|_| telemetry::disable());
    let on = run_churn(|_| telemetry::enable());
    let partial = run_churn(|hour| {
        if hour % 2 == 0 {
            telemetry::enable()
        } else {
            telemetry::disable()
        }
    });
    assert_eq!(off, on, "tracing changed the churn trajectory");
    assert_eq!(off, partial, "toggling tracing changed the trajectory");

    // The same three policies under a scheduled crash on the socket
    // fabric: detection, re-plan and mirror restore must also be
    // invisible to the numerics.
    let run_chaos = |policy: fn(usize)| {
        let mut s = session5(
            Some(FabricSpec::TcpThreads),
            true,
            Some("seed=3,crash=1,first=1,stride=2,delay=0,dup=0"),
        );
        for hour in 0..3 {
            policy(hour);
            s.step_event(hour, 5).unwrap();
        }
        assert_eq!(s.recoveries.len(), 1, "the seeded crash must fire");
        telemetry::reset();
        s.params().unwrap()
    };
    let c_off = run_chaos(|_| telemetry::disable());
    let c_on = run_chaos(|_| telemetry::enable());
    let c_partial = run_chaos(|hour| {
        if hour % 2 == 0 {
            telemetry::enable()
        } else {
            telemetry::disable()
        }
    });
    assert_eq!(c_off, c_on, "tracing changed the recovery trajectory");
    assert_eq!(
        c_off, c_partial,
        "toggling tracing changed the recovery trajectory"
    );
}

/// A fully-sharded rejoin-enabled session config: chaos schedule plus
/// the bounded rejoin window and a short ping timeout (the tests run
/// on loopback, where an undropped pong lands in microseconds).
fn rejoin_cfg(
    fabric: FabricSpec,
    hosts: Option<Vec<u64>>,
    chaos: Option<&str>,
) -> SessionConfig {
    SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric: Some(fabric),
        shard_params: true,
        hosts,
        chaos: chaos.map(String::from),
        rejoin_window_ms: 5000,
        ping_timeout_ms: 200,
        ..Default::default()
    }
}

#[test]
fn partitioned_rank_rejoins_in_place_bitwise_on_tcp_and_hybrid() {
    // Tentpole (DESIGN.md invariant 15, rejoin half, hit path): a
    // coordinator-side chaos point swallows rank 2's PING echo once,
    // raising a false suspicion on a healthy rank. The REJOIN
    // handshake answers inside the window with a fingerprint matching
    // the driver's ledger, so the rank resumes from its RESIDENT
    // shards: zero bytes move, no migration is planned, max_live never
    // clamps — and the session rides the no-chaos trajectory bit for
    // bit through later shrink/regrow churn, on the socket fabric AND
    // the locality-routed hybrid fabric (where the partitioned rank
    // shares a host with the coordinator, so the handshake runs over
    // the shm lane).
    let chaos = "seed=11,crash=0,delay=0,dup=0,drop_ping=2,drop_first=2";
    for hosts in [None, Some(vec![0u64, 1, 0])] {
        let fabric = if hosts.is_some() {
            FabricSpec::HybridThreads
        } else {
            FabricSpec::TcpThreads
        };
        let mut chaotic = Session::new(
            tiny_cluster3(),
            Arc::new(CephaloPlanner::default()),
            rejoin_cfg(fabric, hosts.clone(), Some(chaos)),
        )
        .unwrap();
        let mut graceful = Session::new(
            tiny_cluster3(),
            Arc::new(CephaloPlanner::default()),
            rejoin_cfg(FabricSpec::Local, None, None),
        )
        .unwrap();
        let mut solo = reference();

        // Hour 0: the drop fires at the pre-step poll (poll 2) while
        // all three ranks are active. Hours 1–2: ordinary elastic
        // churn AFTER the heal — the rejoined rank departs gracefully
        // and returns, proving nothing about its state went stale.
        let churn = [3usize, 2, 3];
        for (hour, &size) in churn.iter().enumerate() {
            chaotic.step_event(hour, size).unwrap();
            graceful.step_event(hour, size).unwrap();
            for _ in 0..STEPS_PER_EVENT {
                let idx = solo.history.len();
                solo.step(idx).unwrap();
            }
            assert_eq!(
                chaotic.params().unwrap(),
                solo.params(),
                "rejoin perturbed the trajectory after hour {hour} \
                 (hosts={hosts:?})"
            );
        }
        assert!(
            chaotic.recoveries.is_empty(),
            "a healed partition must not migrate (hosts={hosts:?}): {:?}",
            chaotic.recoveries
        );
        assert_eq!(chaotic.rejoins.len(), 1, "hosts={hosts:?}");
        let rj = &chaotic.rejoins[0];
        assert_eq!(rj.rank, 2);
        assert!(rj.hit, "matching fingerprint must resume in place");
        assert_eq!(rj.moved_state_elems, 0, "a hit moves zero bytes");
        assert!(rj.attempts >= 1);
        assert_eq!(chaotic.max_live(), 3, "rejoined rank stays live");
        assert_eq!(chaotic.current_size(), 3);
        assert_eq!(chaotic.steps_run(), graceful.steps_run());
        assert_eq!(
            chaotic.params().unwrap(),
            graceful.params().unwrap(),
            "rejoin diverged from the fault-free session (hosts={hosts:?})"
        );
    }
}

#[test]
fn tainted_rejoin_restreams_from_the_mirror_bitwise() {
    // Tentpole (invariant 15, rejoin half, miss path): the `taint`
    // chaos point corrupts the rejoining rank's reported fingerprint
    // once, so the otherwise-clean rejoin takes the re-stream path —
    // the rank is re-admitted exactly like a fresh elastic arrival,
    // its Adam moments and weight slice re-streamed from the sharded
    // mirror while the membership stays put. Rejoin ≡ departure +
    // arrival: the trajectory still matches a fault-free run bit for
    // bit, and state really moved.
    let chaos =
        "seed=11,crash=0,delay=0,dup=0,drop_ping=2,drop_first=2,taint=2";
    let mut chaotic = Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        rejoin_cfg(FabricSpec::TcpThreads, None, Some(chaos)),
    )
    .unwrap();
    let mut graceful = Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        rejoin_cfg(FabricSpec::Local, None, None),
    )
    .unwrap();
    let mut solo = reference();

    for hour in 0..3 {
        chaotic.step_event(hour, 3).unwrap();
        graceful.step_event(hour, 3).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = solo.history.len();
            solo.step(idx).unwrap();
        }
        assert_eq!(
            chaotic.params().unwrap(),
            solo.params(),
            "tainted rejoin left the trajectory after hour {hour}"
        );
    }
    assert!(
        chaotic.recoveries.is_empty(),
        "no rank died; the re-stream is a rejoin, not a recovery: {:?}",
        chaotic.recoveries
    );
    assert_eq!(chaotic.rejoins.len(), 1);
    let rj = &chaotic.rejoins[0];
    assert_eq!(rj.rank, 2);
    assert!(!rj.hit, "the tainted digest must force the re-stream path");
    assert!(
        rj.moved_state_elems > 0,
        "a re-stream rejoin must move the rank's state over the wire"
    );
    assert_eq!(chaotic.max_live(), 3, "re-streamed rank stays live");
    assert_eq!(chaotic.current_size(), 3);
    assert_eq!(chaotic.steps_run(), graceful.steps_run());
    assert_eq!(
        chaotic.params().unwrap(),
        graceful.params().unwrap(),
        "re-stream rejoin diverged from the fault-free session"
    );
}

#[test]
fn sharded_mirror_recovery_matches_the_leader_mirror_bitwise() {
    // Tentpole (invariant 15, mirror half): the same seeded crash
    // recovered once from the DEFAULT sharded mirror (each rank's
    // backup on its ring successor) and once from the legacy rank-0
    // flat mirror (`mirror_leader`) produces bitwise-identical
    // parameters — the mirror placement is pure plumbing, invisible to
    // the numerics. Both sessions also stay on the single-worker
    // reference trajectory throughout.
    let chaos = "seed=3,crash=1,first=1,stride=2,delay=0,dup=0";
    let cfg5 = |mirror_leader: bool| SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        fabric: Some(FabricSpec::TcpThreads),
        shard_params: true,
        chaos: Some(chaos.into()),
        mirror_leader,
        ..Default::default()
    };
    let mut sharded = Session::new(
        tiny5_cluster(),
        Arc::new(CephaloPlanner::default()),
        cfg5(false),
    )
    .unwrap();
    let mut leader = Session::new(
        tiny5_cluster(),
        Arc::new(CephaloPlanner::default()),
        cfg5(true),
    )
    .unwrap();
    let mut solo = reference();

    for hour in 0..3 {
        sharded.step_event(hour, 5).unwrap();
        leader.step_event(hour, 5).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = solo.history.len();
            solo.step(idx).unwrap();
        }
        assert_eq!(
            sharded.params().unwrap(),
            solo.params(),
            "sharded-mirror recovery left the trajectory at hour {hour}"
        );
        assert_eq!(
            leader.params().unwrap(),
            solo.params(),
            "leader-mirror recovery left the trajectory at hour {hour}"
        );
    }
    for s in [&sharded, &leader] {
        assert_eq!(s.recoveries.len(), 1, "{:?}", s.recoveries);
        assert_eq!(s.recoveries[0].ranks, vec![4]);
    }
    assert_eq!(
        sharded.recoveries[0].migration_bytes,
        leader.recoveries[0].migration_bytes,
        "both mirrors must stream the same recovery volume"
    );
    assert_eq!(
        sharded.params().unwrap(),
        leader.params().unwrap(),
        "mirror placement leaked into the numerics"
    );
}

#[test]
fn corrupted_frame_declares_the_rank_dead_and_recovery_stays_bitwise() {
    // Satellite: wire corruption is a fail-stop event, not silent data
    // damage. Rank 2's PING reply has one byte flipped after its CRC
    // was computed; the coordinator's checksum verification kills the
    // lane, the liveness poll declares the rank dead, and the session
    // recovers from the mirror — bitwise equal to a graceful departure
    // of the same rank.
    use cephalo::coordinator::elastic::plan_migration;
    use cephalo::sharding::ShardLayout;
    use cephalo::transport::{
        ChaosOpts, DistConfig, DistDriver, FaultPlan,
    };

    let member = |batch: usize, ratio: f64| WorkerSpec {
        batch,
        state_ratio: ratio,
        name: String::new(),
    };
    let membership =
        || vec![member(4, 0.5), member(2, 0.3), member(2, 0.2)];
    let mut plan = FaultPlan::quiet(3);
    plan.faults[2].corrupt_pong_after_step = Some(0);
    let cfg = DistConfig { seed: 5, ft: true, ..Default::default() };
    let mut corrupted = DistDriver::launch_with_chaos(
        FabricSpec::TcpThreads,
        3,
        cfg.clone(),
        membership(),
        Some(ChaosOpts { plan, cli_spec: None }),
    )
    .unwrap();
    let mut graceful =
        DistDriver::launch(FabricSpec::TcpThreads, 3, cfg, membership())
            .unwrap();

    corrupted.step(0).unwrap();
    graceful.step(0).unwrap();
    assert_eq!(
        corrupted.poll_failures().dead,
        vec![2],
        "a CRC-failed frame must fail the sender's liveness check"
    );
    assert!(graceful.poll_failures().is_empty());

    // Same shrink on both drivers; the corrupted one must source the
    // departed rank's ranges from the mirror (the rank is a zombie:
    // alive but excluded), the graceful one streams from rank 2.
    let new_membership = vec![member(4, 0.6), member(4, 0.4)];
    let survivors = vec![Some(0), Some(1)];
    for d in [&mut corrupted, &mut graceful] {
        let old = d.layout().clone();
        let new = ShardLayout::by_ratios(old.len(), &[0.6, 0.4]);
        let (transfers, _, _) = plan_migration(&old, &new, &survivors);
        d.migrate(new_membership.clone(), &survivors, &transfers)
            .unwrap();
    }
    for s in 1..3 {
        corrupted.step(s).unwrap();
        graceful.step(s).unwrap();
    }
    assert_eq!(
        corrupted.gather_params().unwrap(),
        graceful.gather_params().unwrap(),
        "corruption-triggered recovery diverged from the graceful path"
    );
    corrupted.shutdown();
    graceful.shutdown();
}
