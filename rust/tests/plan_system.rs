//! Plan-subsystem integration: every strategy behind one trait.
//!
//! Covers the refactor's contract surface: registry completeness, DP
//! parity through the trait, cache hit == miss reproduction,
//! `Assignment::validate` for every registered planner on the tiny
//! test cluster, parallel-sweep determinism, and planner-attributed
//! error messages.

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::optimizer::DpOptimizer;
use cephalo::plan::{sweep, CephaloPlanner, PlanCache, Planner,
                    PlannerRegistry};
use cephalo::testkit::{check, tiny_cluster};

fn tiny_workload(model: &str) -> Workload {
    Workload::prepare(tiny_cluster(), model, 42).unwrap()
}

#[test]
fn registry_reaches_cephalo_all_baselines_and_ablations() {
    let r = PlannerRegistry::with_defaults();
    // Acceptance: cephalo (DP), the five baselines and the ablation
    // variants all resolve by name.
    for name in [
        "cephalo",
        "megatron",
        "flashflex",
        "whale",
        "hap",
        "fsdp",
        "cephalo-cb",
        "cephalo-mb",
        "fsdp-even",
    ] {
        let p = r.get(name).unwrap_or_else(|| panic!("missing {name}"));
        assert!(!p.name().is_empty());
    }
    assert_eq!(r.names().len(), 9);
}

#[test]
fn dp_through_trait_is_byte_identical_to_direct_call() {
    let w = tiny_workload("BERT-Large");
    for batch in [4usize, 8, 12] {
        let (direct, _) =
            DpOptimizer::default().solve(&w.profile, batch).unwrap();
        let through_trait = CephaloPlanner::default()
            .plan(&w.ctx(batch))
            .unwrap()
            .assignment
            .expect("cephalo always yields an assignment");
        assert_eq!(through_trait, direct, "batch {batch}");
    }
}

#[test]
fn prop_cache_hits_reproduce_misses_exactly() {
    let w = tiny_workload("BERT-Large");
    // `dyn Planner` carries no unwind-safety bound; the property never
    // observes a broken invariant across unwinds (registry is
    // read-only, cache is Mutex/atomic).
    let registry =
        std::panic::AssertUnwindSafe(PlannerRegistry::with_defaults());
    let cache = PlanCache::new();
    check("cache-hit-parity", 40, |g| {
        let batch = 2 * g.usize_in(1, 12); // even, fits the tiny pair
        let name = *g.pick(&["cephalo", "whale", "fsdp", "cephalo-mb"]);
        let planner = registry.get(name).unwrap();
        let first = cache.get_or_plan(&*planner, &w.ctx(batch));
        let second = cache.get_or_plan(&*planner, &w.ctx(batch));
        match (first, second) {
            (Ok(a), Ok(b)) => {
                assert!(b.diagnostics.cache_hit);
                assert_eq!(a.assignment, b.assignment);
                assert_eq!(a.iter_latency, b.iter_latency);
                assert_eq!(a.throughput, b.throughput);
                assert_eq!(a.config, b.config);
                assert_eq!(a.planner, b.planner);
            }
            (Err(a), Err(b)) => assert_eq!(a, b),
            (a, b) => panic!("{name} @{batch}: {a:?} vs {b:?}"),
        }
    });
    assert!(cache.hits() > 0);
}

#[test]
fn every_planner_assignment_validates_on_the_tiny_cluster() {
    let w = tiny_workload("BERT-Large");
    let registry = PlannerRegistry::with_defaults();
    let batch = 8;
    let mut validated = 0;
    for planner in registry.planners() {
        match planner.plan(&w.ctx(batch)) {
            Ok(out) => {
                assert!(out.throughput > 0.0, "{}", planner.name());
                if let Some(asg) = &out.assignment {
                    asg.validate(&w.profile, batch).unwrap_or_else(|e| {
                        panic!("{}: invalid assignment: {e}",
                               planner.name())
                    });
                }
            }
            Err(e) => {
                // Clean, attributed planning failures are acceptable
                // (a tiny 2-GPU cluster is hostile to pipelining).
                assert_eq!(e.planner(), Some(planner.name()), "{e}");
            }
        }
        if planner
            .plan(&w.ctx(batch))
            .ok()
            .and_then(|o| o.assignment)
            .is_some()
        {
            validated += 1;
        }
    }
    // At least the FSDP-division family must produce assignments.
    assert!(validated >= 4, "only {validated} planners yielded \
                             assignments");
}

#[test]
fn sweep_grid_is_deterministic_and_ordered() {
    let w = tiny_workload("BERT-Large");
    let registry = PlannerRegistry::with_defaults();
    let batches = [4usize, 8, 16];
    let a = sweep(&w.ctx(0), registry.planners(), &batches, None);
    let b = sweep(&w.ctx(0), registry.planners(), &batches, None);
    assert_eq!(a.len(), registry.len() * batches.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.planner, y.planner);
        assert_eq!(x.batch, y.batch);
        assert_eq!(x.throughput(), y.throughput());
    }
    // Planner-major order matches the registry.
    for (i, cell) in a.iter().enumerate() {
        assert_eq!(cell.planner,
                   registry.planners()[i / batches.len()].name());
        assert_eq!(cell.batch, batches[i % batches.len()]);
    }
}

#[test]
fn sweep_admits_sharded_configs_that_oom_under_leader_residency() {
    // Acceptance (tentpole): with fully-sharded parameter accounting
    // the planner admits a configuration that OOMs under the old
    // leader-resident accounting — the "supports larger models" half
    // of the abstract, planner-side.
    use cephalo::memory::ParamResidency;
    use cephalo::optimizer::DpOptimizer;
    use cephalo::plan::PlanContext;
    use cephalo::testkit::{apply_residency_window, window8_cluster};
    use std::sync::Arc;

    // The shared residency window: every GPU fits its compute plus a
    // fully-sharded state share, but not a replicated weight copy
    // (see `testkit::apply_residency_window` for the construction).
    let w = Workload::prepare(window8_cluster(), "BERT-Large", 42)
        .unwrap();
    let mut profile = w.profile.clone();
    apply_residency_window(&mut profile);
    let ctx =
        PlanContext::new(&w.cluster, &w.model, &profile, &w.oracle, 0);
    let sharded: Arc<dyn Planner> = Arc::new(CephaloPlanner {
        simulate: false,
        ..Default::default()
    });
    let leader: Arc<dyn Planner> = Arc::new(CephaloPlanner {
        opts: DpOptimizer {
            residency: ParamResidency::LeaderResident,
            ..Default::default()
        },
        simulate: false,
        ..Default::default()
    });
    let cells = sweep(&ctx, &[sharded, leader], &[8], None);
    assert_eq!(cells.len(), 2);
    // Sharded accounting admits the config...
    let admitted = cells[0]
        .result
        .as_ref()
        .expect("fully-sharded accounting must admit this config");
    let asg = admitted.assignment.as_ref().unwrap();
    asg.validate_resident(&profile, 8, ParamResidency::FullySharded)
        .expect("sharded accounting fits");
    // ...and per-GPU parameter bytes are proportional to r_i.
    let total = profile.total_params;
    for g in &asg.per_gpu {
        assert_eq!(
            ParamResidency::FullySharded.param_bytes(total, g.state_ratio),
            total * 4.0 * g.state_ratio
        );
    }
    // Leader-resident accounting OOMs on the same inputs.
    let err = cells[1].result.as_ref().unwrap_err();
    assert!(err.is_oom(), "expected leader-resident OOM, got: {err}");
}

#[test]
fn sweep_admits_unit_sharded_configs_that_oom_under_whole_gather() {
    // Acceptance (FSDP units): with per-unit transient accounting the
    // planner admits a configuration that OOMs under whole-model
    // gather — the peak parameter bytes scale with the largest unit,
    // not with the model.
    use cephalo::memory::ParamResidency;
    use cephalo::optimizer::DpOptimizer;
    use cephalo::plan::PlanContext;
    use cephalo::testkit::{apply_unit_residency_window, window8_cluster};
    use std::sync::Arc;

    let units = 16;
    // The unit residency window: every GPU fits its compute plus the
    // double-buffered unit pair and a state share, but not a
    // whole-model gather buffer (see `apply_unit_residency_window`).
    let w = Workload::prepare(window8_cluster(), "BERT-Large", 42)
        .unwrap();
    let mut profile = w.profile.clone();
    apply_unit_residency_window(&mut profile, units);
    let ctx =
        PlanContext::new(&w.cluster, &w.model, &profile, &w.oracle, 0);
    let unit: Arc<dyn Planner> = Arc::new(CephaloPlanner {
        opts: DpOptimizer {
            residency: ParamResidency::UnitSharded { units },
            ..Default::default()
        },
        simulate: false,
        ..Default::default()
    });
    let gather: Arc<dyn Planner> = Arc::new(CephaloPlanner {
        opts: DpOptimizer {
            residency: ParamResidency::WholeModelGather,
            ..Default::default()
        },
        simulate: false,
        ..Default::default()
    });
    let cells = sweep(&ctx, &[unit, gather], &[8], None);
    assert_eq!(cells.len(), 2);
    // Unit accounting admits the config and validates under it...
    let admitted = cells[0]
        .result
        .as_ref()
        .expect("unit-sharded accounting must admit this config");
    let asg = admitted.assignment.as_ref().unwrap();
    asg.validate_resident(
        &profile,
        8,
        ParamResidency::UnitSharded { units },
    )
    .expect("unit accounting fits");
    // ...with per-GPU peak parameter bytes = resident shard + the
    // double-buffered unit pair, strictly below the gather peak.
    let total = profile.total_params;
    let unit_res = ParamResidency::UnitSharded { units };
    for g in &asg.per_gpu {
        assert_eq!(
            unit_res.param_bytes(total, g.state_ratio),
            total * 4.0 * g.state_ratio
                + 2.0 * total * 4.0 / units as f64
        );
        assert!(
            unit_res.param_bytes(total, g.state_ratio)
                < ParamResidency::WholeModelGather
                    .param_bytes(total, g.state_ratio)
        );
    }
    // Whole-model gather OOMs on the same inputs.
    let err = cells[1].result.as_ref().unwrap_err();
    assert!(err.is_oom(), "expected whole-gather OOM, got: {err}");
}

#[test]
fn oom_errors_name_planner_and_configuration() {
    // Whale fully replicates GPT 2.7B's ~44 GB state: guaranteed OOM on
    // cluster A, and the error must say who and which config.
    let w = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
        .unwrap();
    let registry = PlannerRegistry::with_defaults();
    let err = registry
        .get("whale")
        .unwrap()
        .plan(&w.ctx(128))
        .unwrap_err();
    assert!(err.is_oom(), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("[Whale]"), "{msg}");
    assert!(msg.contains("replicated state"), "{msg}");
    assert!(msg.contains("GB"), "{msg}");
}
