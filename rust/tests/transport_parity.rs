//! Satellite: transport parity. The segmented ring collectives over a
//! REAL message plane — in-process channels (`LocalTransport`),
//! loopback sockets (`TcpTransport`, threaded ranks), /dev/shm ring
//! lanes (`ShmTransport`) and the locality-routed composition
//! (`HybridTransport`) — are BITWISE-equal to the in-process
//! `collectives::ring_*` and to the `direct_*` references, over uneven
//! and zero-`r_i` layouts. DESIGN.md invariants 8/9 extended to the
//! wire (invariant 10: the wire — including which lane each hop takes
//! and which order the ring walks — is bitwise-invisible).

use cephalo::collectives as inproc;
use cephalo::sharding::ShardLayout;
use cephalo::testkit::{check, Gen};
use cephalo::transport::collectives::RingOrder;
use cephalo::transport::shm::fresh_dir;
use cephalo::transport::{
    collectives as wire, ChaosConfig, ChaosTransport, CrashMode, FaultPlan,
    HostTopology, HybridTransport, LocalFabric, ShmFabric, Transport,
};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Run one collective round over an already-built fabric: each
/// endpoint executes `f` on its own thread; results in rank order.
fn run_ranks<T: Send>(
    eps: Vec<Box<dyn Transport>>,
    f: impl Fn(&mut dyn Transport) -> T + Sync,
) -> Vec<T> {
    std::thread::scope(|s| {
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let f = &f;
                s.spawn(move || f(ep.as_mut()))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn local_fabric(world: usize) -> Vec<Box<dyn Transport>> {
    LocalFabric::new(world)
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

fn shm_fabric(world: usize) -> Vec<Box<dyn Transport>> {
    ShmFabric::new(world)
        .expect("shm fabric")
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

/// Hybrid fabric under `topo`: same-host pairs ride fresh shm lanes,
/// cross-host pairs the channel fabric.
fn hybrid_fabric(topo: &HostTopology) -> Vec<Box<dyn Transport>> {
    let dir = fresh_dir();
    LocalFabric::new(topo.world_size())
        .into_iter()
        .map(|slow| {
            Box::new(
                HybridTransport::wrap(Box::new(slow), &dir, topo.clone())
                    .expect("hybrid fabric"),
            ) as Box<dyn Transport>
        })
        .collect()
}

/// A random host map over up to three hosts (covers all-same-host,
/// all-distinct and mixed placements).
fn random_topology(g: &mut Gen, world: usize) -> HostTopology {
    HostTopology::new(
        (0..world).map(|_| g.usize_in(0, 2) as u64).collect(),
    )
}

/// Channel fabric with deterministic fault injection on every rank.
fn chaotic_fabric(world: usize, plan: &FaultPlan) -> Vec<Box<dyn Transport>> {
    LocalFabric::new(world)
        .into_iter()
        .map(|e| {
            Box::new(ChaosTransport::new(e, plan, CrashMode::Error))
                as Box<dyn Transport>
        })
        .collect()
}

/// Crash-free noise: delay and duplicate probabilities only.
fn noise(delay: f64, dup: f64) -> ChaosConfig {
    ChaosConfig {
        crash_ranks: 0,
        first_crash_step: 0,
        crash_step_stride: 1,
        delay_prob: delay,
        max_delay_ms: 1,
        dup_prob: dup,
        ..Default::default()
    }
}

/// One parity case: random (possibly sparse) layout, random data; both
/// collectives over the given fabric against both references.
fn parity_case(g: &mut Gen, eps: Vec<Box<dyn Transport>>) {
    let n = eps.len();
    let len = g.usize_in(0, 300);
    let ratios = if g.bool() { g.ratios(n) } else { g.sparse_ratios(n) };
    let layout = ShardLayout::by_ratios(len, &ratios);

    let shards: Vec<Vec<f32>> = (0..n)
        .map(|r| g.vec_f32(layout.size(r), 2.0))
        .collect();
    let full: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, 2.0)).collect();

    let expect_ag = inproc::ring_allgather(&shards, &layout);
    assert_eq!(expect_ag, inproc::direct_allgather(&shards, &layout));
    let expect_rs = inproc::ring_reduce_scatter(&full, &layout);

    let got = run_ranks(eps, |t| {
        let r = t.rank();
        let ag = wire::ring_allgather(t, &shards[r], &layout).unwrap();
        let rs = wire::ring_reduce_scatter(t, &full[r], &layout).unwrap();
        (ag, rs)
    });
    for (r, (ag, rs)) in got.iter().enumerate() {
        assert_eq!(
            bits(ag),
            bits(&expect_ag),
            "rank {r} allgather differs from the in-process ring"
        );
        assert_eq!(
            bits(rs),
            bits(&expect_rs[r]),
            "rank {r} reduce-scatter differs bitwise"
        );
    }
    // The wire RS also agrees with direct_* within float tolerance
    // (direct uses a different, non-ring summation order).
    let direct = inproc::direct_reduce_scatter(&full, &layout);
    for (r, (_, rs)) in got.iter().enumerate() {
        for (i, (a, b)) in direct[r].iter().zip(rs).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                "rank {r} elem {i}: direct {a} vs wire {b}"
            );
        }
    }
}

#[test]
fn prop_local_fabric_collectives_match_inprocess_bitwise() {
    check("wire-parity-local", 60, |g| {
        let n = g.usize_in(1, 6);
        parity_case(g, local_fabric(n));
    });
}

#[test]
fn prop_tcp_loopback_collectives_match_inprocess_bitwise() {
    // Fewer cases than the channel fabric: every case pays a full
    // rendezvous + mesh handshake over real sockets.
    check("wire-parity-tcp", 12, |g| {
        let n = g.usize_in(2, 5);
        let eps = cephalo::transport::tcp::thread_fabric(n).unwrap();
        parity_case(g, eps);
    });
}

#[test]
fn prop_fault_plans_are_pure_in_seed_world_and_config() {
    // The replayability contract: a fault plan is a pure function of
    // (seed, world, config), so a chaos run can be reproduced exactly
    // from its logged seed.
    check("fault-plan-purity", 40, |g| {
        let seed = g.usize_in(0, 1 << 30) as u64;
        let world = g.usize_in(1, 9);
        let cfg = ChaosConfig {
            crash_ranks: g.usize_in(0, world),
            first_crash_step: g.usize_in(0, 5) as u64,
            crash_step_stride: g.usize_in(1, 4) as u64,
            delay_prob: g.f64_in(0.0, 1.0),
            max_delay_ms: g.usize_in(0, 3) as u64,
            dup_prob: g.f64_in(0.0, 1.0),
            ..Default::default()
        };
        let plan = FaultPlan::generate(seed, world, &cfg);
        assert_eq!(plan, FaultPlan::generate(seed, world, &cfg));
        assert_eq!(plan.world(), world);
        // Rank 0 (the coordinator) is never scheduled to crash, and
        // crash steps fall on the highest ranks at increasing steps.
        assert_eq!(plan.for_rank(0).crash_after_step, None);
        let crash_steps: Vec<u64> = (1..world)
            .rev()
            .filter_map(|r| plan.for_rank(r).crash_after_step)
            .collect();
        assert!(crash_steps.windows(2).all(|w| w[0] < w[1]));
    });
}

#[test]
fn prop_chaotic_fabric_is_bitwise_invisible() {
    // Delay + duplicate injection on every rank must not change a
    // single bit of any collective result — invariant 10 extended to
    // a lossy-looking wire.
    check("wire-parity-chaos", 30, |g| {
        let n = g.usize_in(1, 5);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let plan = FaultPlan::generate(seed, n, &noise(0.3, 0.3));
        parity_case(g, chaotic_fabric(n, &plan));
    });
}

#[test]
fn chaotic_runs_with_the_same_plan_are_identical() {
    // Same seed + same plan ⇒ the same fault schedule fires at the
    // same points and the collective output is bit-identical run over
    // run — and equal to the clean reference, since injected faults
    // are invisible by construction.
    let n = 3;
    let len = 101;
    let layout = ShardLayout::by_ratios(len, &[0.5, 0.2, 0.3]);
    let full: Vec<Vec<f32>> = (0..n)
        .map(|r| {
            (0..len).map(|i| ((r + 2) * (i + 1)) as f32 * 0.125).collect()
        })
        .collect();
    let expect = inproc::ring_reduce_scatter(&full, &layout);
    let cfg = noise(0.4, 0.4);
    let run = |seed: u64| {
        let plan = FaultPlan::generate(seed, n, &cfg);
        assert_eq!(plan, FaultPlan::generate(seed, n, &cfg));
        run_ranks(chaotic_fabric(n, &plan), |t| {
            wire::ring_reduce_scatter(t, &full[t.rank()], &layout).unwrap()
        })
    };
    let a = run(17);
    let b = run(17);
    for r in 0..n {
        assert_eq!(bits(&a[r]), bits(&b[r]), "rank {r} diverged across runs");
        assert_eq!(
            bits(&a[r]),
            bits(&expect[r]),
            "rank {r} diverged from the clean reference"
        );
    }
}

#[test]
fn barrier_completes_under_delay_only_faults() {
    // Liveness: pure message delay slows a barrier but can never
    // deadlock or fail it.
    let n = 4;
    let plan = FaultPlan::generate(3, n, &noise(1.0, 0.0));
    let done = run_ranks(chaotic_fabric(n, &plan), |t| {
        for _ in 0..3 {
            t.barrier().unwrap();
        }
        true
    });
    assert_eq!(done, vec![true; n]);
}

#[test]
fn prop_shm_fabric_collectives_match_inprocess_bitwise() {
    // The /dev/shm ring lanes are wire too: invariant 10 holds over
    // mmap'd memory exactly as over channels and sockets.
    check("wire-parity-shm", 30, |g| {
        let n = g.usize_in(1, 5);
        parity_case(g, shm_fabric(n));
    });
}

#[test]
fn prop_hybrid_fabric_collectives_match_inprocess_bitwise() {
    // Random host maps: whichever lane each hop takes — shm for
    // same-host pairs, the slow fabric across hosts — the collective
    // result is bit-identical to the in-process reference.
    check("wire-parity-hybrid", 20, |g| {
        let n = g.usize_in(1, 5);
        let topo = random_topology(g, n);
        parity_case(g, hybrid_fabric(&topo));
    });
}

#[test]
fn prop_chaos_over_hybrid_is_bitwise_invisible() {
    // The fault injector composes over the locality router: delay and
    // duplicate injection on a mixed shm/channel mesh must not change
    // a single bit.
    check("wire-parity-hybrid-chaos", 12, |g| {
        let n = g.usize_in(1, 4);
        let topo = random_topology(g, n);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let plan = FaultPlan::generate(seed, n, &noise(0.3, 0.3));
        let eps: Vec<Box<dyn Transport>> = hybrid_fabric(&topo)
            .into_iter()
            .map(|e| {
                Box::new(ChaosTransport::new(e, &plan, CrashMode::Error))
                    as Box<dyn Transport>
            })
            .collect();
        parity_case(g, eps);
    });
}

#[test]
fn shm_lanes_preserve_fifo_self_send_and_barrier() {
    // The point-to-point contract the collectives build on, exercised
    // directly over mmap rings: per-pair FIFO, self-sends, per-source
    // demultiplexing, and the gather-to-0 barrier.
    let n = 3;
    let done = run_ranks(shm_fabric(n), |t| {
        let me = t.rank();
        for to in 0..n {
            t.send_bytes(to, &[me as u8, 1]).unwrap();
            t.send_bytes(to, &[me as u8, 2]).unwrap();
        }
        t.send_f32(me, &[me as f32 * 0.5]).unwrap();
        // Demux by source, FIFO within each source.
        for from in (0..n).rev() {
            assert_eq!(t.recv_bytes(from).unwrap(), vec![from as u8, 1]);
            assert_eq!(t.recv_bytes(from).unwrap(), vec![from as u8, 2]);
        }
        assert_eq!(t.recv_f32(me).unwrap(), vec![me as f32 * 0.5]);
        for _ in 0..3 {
            t.barrier().unwrap();
        }
        true
    });
    assert_eq!(done, vec![true; n]);
}

#[test]
fn prop_reordered_rings_are_bitwise_invisible() {
    // The locality-sorted ring walks the ranks in topology order, not
    // rank order. AllGather only moves bytes, so ANY order must be
    // bitwise-equal to the classic ring; ReduceScatter re-associates
    // the sum, so a reordered ring is run-over-run deterministic and
    // tolerance-equal to the classic result, while the identity order
    // collapses to the classic schedule exactly.
    check("wire-parity-ordered", 25, |g| {
        let n = g.usize_in(1, 5);
        let topo = random_topology(g, n);
        let order = RingOrder::from_topology(&topo, n);
        let len = g.usize_in(0, 200);
        let ratios =
            if g.bool() { g.ratios(n) } else { g.sparse_ratios(n) };
        let layout = ShardLayout::by_ratios(len, &ratios);
        let shards: Vec<Vec<f32>> =
            (0..n).map(|r| g.vec_f32(layout.size(r), 2.0)).collect();
        let full: Vec<Vec<f32>> =
            (0..n).map(|_| g.vec_f32(len, 2.0)).collect();
        let expect_ag = inproc::ring_allgather(&shards, &layout);
        let expect_rs = inproc::ring_reduce_scatter(&full, &layout);

        let run = |eps: Vec<Box<dyn Transport>>, ord: RingOrder| {
            let (shards, full, layout) = (&shards, &full, &layout);
            run_ranks(eps, move |t| {
                let r = t.rank();
                let ag = wire::ring_allgather_ordered(
                    t, &shards[r], layout, &ord,
                )
                .unwrap();
                let rs = wire::ring_reduce_scatter_ordered(
                    t, &full[r], layout, &ord,
                )
                .unwrap();
                (ag, rs)
            })
        };
        let got = run(hybrid_fabric(&topo), order.clone());
        let again = run(local_fabric(n), order.clone());
        let ident = run(local_fabric(n), RingOrder::identity(n));
        for r in 0..n {
            assert_eq!(
                bits(&got[r].0),
                bits(&expect_ag),
                "rank {r} ordered allgather differs from classic"
            );
            // Reordered RS: deterministic across fabrics and runs...
            assert_eq!(
                bits(&got[r].1),
                bits(&again[r].1),
                "rank {r} ordered RS differs across fabrics"
            );
            // ...and numerically the same sum.
            for (i, (a, b)) in
                expect_rs[r].iter().zip(&got[r].1).enumerate()
            {
                assert!(
                    (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                    "rank {r} elem {i}: classic {a} vs ordered {b}"
                );
            }
            // The identity order IS the classic schedule, bit for bit.
            assert_eq!(bits(&ident[r].0), bits(&expect_ag));
            assert_eq!(bits(&ident[r].1), bits(&expect_rs[r]));
        }
    });
}

#[test]
fn composed_rs_then_ag_over_sockets_is_an_allreduce() {
    // Invariant 4's composition, now over a socket fabric: RS then AG
    // equals the direct AllReduce (tolerance: summation order).
    let n = 4;
    let layout = ShardLayout::by_ratios(37, &[0.4, 0.0, 0.35, 0.25]);
    let full: Vec<Vec<f32>> = (0..n)
        .map(|r| (0..37).map(|i| ((r + 1) * (i + 1)) as f32 * 0.01).collect())
        .collect();
    let expect = inproc::direct_allreduce(&full, &layout);
    let eps = cephalo::transport::tcp::thread_fabric(n).unwrap();
    let got = run_ranks(eps, |t| {
        let shard =
            wire::ring_reduce_scatter(t, &full[t.rank()], &layout).unwrap();
        wire::ring_allgather(t, &shard, &layout).unwrap()
    });
    for (r, g) in got.iter().enumerate() {
        for (i, (a, b)) in expect.iter().zip(g).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                "rank {r} elem {i}: {a} vs {b}"
            );
        }
    }
}
