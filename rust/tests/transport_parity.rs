//! Satellite: transport parity. The segmented ring collectives over a
//! REAL message plane — in-process channels (`LocalTransport`) and
//! loopback sockets (`TcpTransport`, threaded ranks) — are
//! BITWISE-equal to the in-process `collectives::ring_*` and to the
//! `direct_*` references, over uneven and zero-`r_i` layouts.
//! DESIGN.md invariants 8/9 extended to the wire (invariant 10: the
//! wire is bitwise-invisible).

use cephalo::collectives as inproc;
use cephalo::sharding::ShardLayout;
use cephalo::testkit::{check, Gen};
use cephalo::transport::{collectives as wire, LocalFabric, Transport};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Run one collective round over an already-built fabric: each
/// endpoint executes `f` on its own thread; results in rank order.
fn run_ranks<T: Send>(
    eps: Vec<Box<dyn Transport>>,
    f: impl Fn(&mut dyn Transport) -> T + Sync,
) -> Vec<T> {
    std::thread::scope(|s| {
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let f = &f;
                s.spawn(move || f(ep.as_mut()))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn local_fabric(world: usize) -> Vec<Box<dyn Transport>> {
    LocalFabric::new(world)
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

/// One parity case: random (possibly sparse) layout, random data; both
/// collectives over the given fabric against both references.
fn parity_case(g: &mut Gen, eps: Vec<Box<dyn Transport>>) {
    let n = eps.len();
    let len = g.usize_in(0, 300);
    let ratios = if g.bool() { g.ratios(n) } else { g.sparse_ratios(n) };
    let layout = ShardLayout::by_ratios(len, &ratios);

    let shards: Vec<Vec<f32>> = (0..n)
        .map(|r| g.vec_f32(layout.size(r), 2.0))
        .collect();
    let full: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, 2.0)).collect();

    let expect_ag = inproc::ring_allgather(&shards, &layout);
    assert_eq!(expect_ag, inproc::direct_allgather(&shards, &layout));
    let expect_rs = inproc::ring_reduce_scatter(&full, &layout);

    let got = run_ranks(eps, |t| {
        let r = t.rank();
        let ag = wire::ring_allgather(t, &shards[r], &layout).unwrap();
        let rs = wire::ring_reduce_scatter(t, &full[r], &layout).unwrap();
        (ag, rs)
    });
    for (r, (ag, rs)) in got.iter().enumerate() {
        assert_eq!(
            bits(ag),
            bits(&expect_ag),
            "rank {r} allgather differs from the in-process ring"
        );
        assert_eq!(
            bits(rs),
            bits(&expect_rs[r]),
            "rank {r} reduce-scatter differs bitwise"
        );
    }
    // The wire RS also agrees with direct_* within float tolerance
    // (direct uses a different, non-ring summation order).
    let direct = inproc::direct_reduce_scatter(&full, &layout);
    for (r, (_, rs)) in got.iter().enumerate() {
        for (i, (a, b)) in direct[r].iter().zip(rs).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                "rank {r} elem {i}: direct {a} vs wire {b}"
            );
        }
    }
}

#[test]
fn prop_local_fabric_collectives_match_inprocess_bitwise() {
    check("wire-parity-local", 60, |g| {
        let n = g.usize_in(1, 6);
        parity_case(g, local_fabric(n));
    });
}

#[test]
fn prop_tcp_loopback_collectives_match_inprocess_bitwise() {
    // Fewer cases than the channel fabric: every case pays a full
    // rendezvous + mesh handshake over real sockets.
    check("wire-parity-tcp", 12, |g| {
        let n = g.usize_in(2, 5);
        let eps = cephalo::transport::tcp::thread_fabric(n).unwrap();
        parity_case(g, eps);
    });
}

#[test]
fn composed_rs_then_ag_over_sockets_is_an_allreduce() {
    // Invariant 4's composition, now over a socket fabric: RS then AG
    // equals the direct AllReduce (tolerance: summation order).
    let n = 4;
    let layout = ShardLayout::by_ratios(37, &[0.4, 0.0, 0.35, 0.25]);
    let full: Vec<Vec<f32>> = (0..n)
        .map(|r| (0..37).map(|i| ((r + 1) * (i + 1)) as f32 * 0.01).collect())
        .collect();
    let expect = inproc::direct_allreduce(&full, &layout);
    let eps = cephalo::transport::tcp::thread_fabric(n).unwrap();
    let got = run_ranks(eps, |t| {
        let shard =
            wire::ring_reduce_scatter(t, &full[t.rank()], &layout).unwrap();
        wire::ring_allgather(t, &shard, &layout).unwrap()
    });
    for (r, g) in got.iter().enumerate() {
        for (i, (a, b)) in expect.iter().zip(g).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                "rank {r} elem {i}: {a} vs {b}"
            );
        }
    }
}
