//! Trace-output validation (invariant-14 satellites): the Chrome
//! trace-event JSON a traced session emits is schema-valid — spans
//! nest properly per track, timestamps are monotone, every live rank
//! shows its gather/compute/reduce-scatter phases, the coordinator
//! shows replan/migrate — and chaos fault instants line up with the
//! seeded `FaultPlan`.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex, MutexGuard};

use cephalo::coordinator::session::{Session, SessionConfig};
use cephalo::plan::CephaloPlanner;
use cephalo::telemetry;
use cephalo::testkit::tiny_cluster3;
use cephalo::transport::FabricSpec;
use cephalo::util::json::Json;

/// The tracer is process-global; every test here toggles and drains
/// it, so they must run one at a time.
fn lock() -> MutexGuard<'static, ()> {
    static M: Mutex<()> = Mutex::new(());
    M.lock().unwrap_or_else(|e| e.into_inner())
}

fn session(chaos: Option<&str>) -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: 8,
        steps_per_event: 2,
        seed: 13,
        min_gpus: 1,
        fabric: Some(FabricSpec::TcpThreads),
        shard_params: true,
        chaos: chaos.map(String::from),
        ..Default::default()
    };
    Session::new(
        tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("session starts on the 3-GPU cluster")
}

#[test]
fn traced_session_writes_a_valid_nested_chrome_trace() {
    let _g = lock();
    telemetry::reset();
    telemetry::enable();
    let mut s = session(None);
    // Shrink then regrow so the replan/migrate path records spans.
    for (hour, &size) in [2usize, 3].iter().enumerate() {
        s.step_event(hour, size).unwrap();
    }
    drop(s); // joins worker threads -> their buffers flush
    let dir = std::env::temp_dir()
        .join(format!("cephalo-trace-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("session.json");
    telemetry::write_chrome_trace(
        &path,
        &[("case", Json::Str("integration".into()))],
    )
    .unwrap();
    telemetry::reset();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let j = Json::parse(&text).expect("trace must be valid JSON");
    let meta = j.field("metadata").unwrap();
    assert!(meta.get("fabric_counters").is_some());
    assert_eq!(meta.get("case").unwrap().as_str(), Some("integration"));
    let evs = j.field("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty());

    // Walk every event: known phases only, timestamps monotone per
    // track in file order, X spans collected per track for nesting.
    let mut spans: BTreeMap<(u64, u64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut cats: BTreeMap<u64, BTreeSet<String>> = BTreeMap::new();
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut timeline_events = 0usize;
    for e in evs {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            continue;
        }
        assert!(ph == "X" || ph == "i", "unexpected phase '{ph}'");
        let pid = e.get("pid").unwrap().as_f64().unwrap() as u64;
        let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let last = last_ts.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
        assert!(
            ts >= *last,
            "timestamps must be monotone per track ({pid},{tid})"
        );
        *last = ts;
        if pid == 1 {
            timeline_events += 1;
        }
        if ph == "X" {
            let dur = e.get("dur").unwrap().as_f64().unwrap();
            assert!(dur >= 0.0, "negative span duration");
            spans.entry((pid, tid)).or_default().push((ts, ts + dur));
            if pid == 0 {
                cats.entry(tid).or_default().insert(
                    e.get("cat").unwrap().as_str().unwrap().to_string(),
                );
            }
        }
    }

    // Spans on one track either nest or are disjoint — never straddle.
    const EPS: f64 = 1e-3;
    for ((pid, tid), track) in &spans {
        let mut open: Vec<f64> = Vec::new(); // enclosing span end times
        for &(start, end) in track {
            while let Some(&top) = open.last() {
                if start >= top - EPS {
                    open.pop();
                } else {
                    break;
                }
            }
            if let Some(&top) = open.last() {
                assert!(
                    end <= top + EPS,
                    "span [{start:.1},{end:.1}] straddles its parent \
                     ending at {top:.1} on track ({pid},{tid})"
                );
            }
            open.push(end);
        }
    }

    // Every rank that stepped shows the per-phase spans; the
    // coordinator (tid 0) also recorded the replan+migrate work; the
    // cross-rank timeline pid carries the reply-assembled step spans.
    for rank in 0..3u64 {
        let c = cats
            .get(&rank)
            .unwrap_or_else(|| panic!("no spans for rank {rank}"));
        for want in ["gather", "compute", "reduce_scatter"] {
            assert!(c.contains(want), "rank {rank} missing '{want}': {c:?}");
        }
    }
    for want in ["replan", "migrate"] {
        assert!(
            cats[&0].contains(want),
            "coordinator missing '{want}': {:?}",
            cats[&0]
        );
    }
    assert!(timeline_events > 0, "no cross-rank timeline events");
}

#[test]
fn chaos_fault_instants_match_the_seeded_plan() {
    let _g = lock();
    telemetry::reset();
    telemetry::enable();
    let mut s = session(Some("seed=3,crash=1,first=1,stride=2,delay=0,dup=0"));
    let plan = s.fault_plan().expect("chaos spec seeds a plan").clone();
    for hour in 0..3 {
        s.step_event(hour, 3).unwrap();
    }
    let dead: Vec<usize> =
        s.recoveries.iter().flat_map(|r| r.ranks.clone()).collect();
    assert!(!dead.is_empty(), "the seeded crash must fire and recover");
    drop(s);
    let events = telemetry::take_events();
    telemetry::reset();

    let crashes: Vec<&telemetry::Event> = events
        .iter()
        .filter(|e| {
            e.cat == "fault" && e.dur_us.is_none()
                && e.name.starts_with("crash ")
        })
        .collect();
    // Every recovered rank fired a step-keyed crash instant, at or
    // after the step its plan scheduled.
    for &r in &dead {
        let scheduled = plan.faults[r]
            .crash_after_step
            .expect("recovered rank must have a scheduled crash");
        let inst = crashes
            .iter()
            .find(|e| e.name.starts_with(&format!("crash r{r} ")))
            .unwrap_or_else(|| {
                panic!("no crash instant for rank {r}: {crashes:?}")
            });
        let fired: u64 =
            inst.name.rsplit_once(" s").unwrap().1.parse().unwrap();
        assert!(
            fired >= scheduled,
            "rank {r} crash instant at step {fired}, before its \
             scheduled step {scheduled}"
        );
    }
    // ... and no rank the plan left quiet recorded one.
    for f in &plan.faults {
        if f.crash_after_step.is_none() {
            assert!(
                !crashes
                    .iter()
                    .any(|e| e.name.starts_with(&format!("crash r{} ", f.rank))),
                "unscheduled rank {} recorded a crash instant",
                f.rank
            );
        }
    }
    // The fired fault also ticked the chaos counter.
    assert!(telemetry::counters().snapshot()["chaos_faults"] >= 1);
}
