//! Acceptance: `cargo test` in the DEFAULT (no-`xla`) build runs a live
//! elastic training session end to end — ≥3 churn events with real
//! state migration — and after every migration the parameters are
//! BITWISE-identical to a single-worker reference trained on the same
//! batches. Recurring memberships must be served by the PlanCache.
//!
//! Why bitwise equality is even possible: the native backend quantizes
//! per-token gradient contributions onto a dyadic grid whose partial
//! sums are exactly representable in f32 (see `exec::native`), so
//! gradient summation is associative — any worker split, ring schedule
//! or shard layout yields the same totals, and Adam/allgather are
//! elementwise from there.

use std::sync::Arc;

use cephalo::coordinator::session::{Session, SessionConfig};
use cephalo::exec::{NativeExecutor, SurrogateSpec};
use cephalo::plan::CephaloPlanner;
use cephalo::testkit::tiny_cluster;
use cephalo::trainer::{TrainConfig, Trainer, WorkerSpec};

const SEED: u64 = 11;
const BATCH: usize = 8;
const STEPS_PER_EVENT: usize = 3;

fn session() -> Session {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: BATCH,
        steps_per_event: STEPS_PER_EVENT,
        seed: SEED,
        min_gpus: 1,
        ..Default::default()
    };
    Session::new(
        tiny_cluster(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("session starts on the tiny cluster")
}

fn reference() -> Trainer {
    // One worker, the whole batch, the whole state — same surrogate,
    // seed and corpus stream as the session's trainer.
    let cfg = TrainConfig {
        steps: 0,
        seed: SEED,
        log_every: 0,
        ..Default::default()
    };
    Trainer::from_executor(
        Box::new(NativeExecutor::new(SurrogateSpec::default())),
        vec![WorkerSpec {
            batch: BATCH,
            state_ratio: 1.0,
            name: "solo".into(),
        }],
        cfg,
    )
    .unwrap()
}

#[test]
fn live_session_stays_bitwise_on_the_reference_trajectory() {
    let mut session = session();
    let mut reference = reference();
    assert_eq!(
        session.trainer().params(),
        reference.params(),
        "same seed must give the same init"
    );

    // Explicit churn: shrink to 1 GPU, regrow to 2, repeat — five
    // events, four real migrations, both recurring memberships seen
    // twice or more.
    let churn = [2usize, 1, 2, 1, 2];
    for (hour, &size) in churn.iter().enumerate() {
        let report = session.step_event(hour, size).unwrap();
        assert_eq!(report.gpus, size);
        assert_eq!(report.steps, STEPS_PER_EVENT);
        for _ in 0..STEPS_PER_EVENT {
            let idx = reference.history.len();
            reference.step(idx).unwrap();
        }
        assert_eq!(
            session.trainer().params(),
            reference.params(),
            "params diverged after event {hour} (membership {size})"
        );
        // Losses ride the same trajectory too (f64 reduction order may
        // differ across worker counts, so compare approximately).
        let s_loss = session.trainer().history.last().unwrap().mean_loss;
        let r_loss = reference.history.last().unwrap().mean_loss;
        assert!(
            (s_loss - r_loss).abs() <= 1e-9 * s_loss.abs().max(1.0),
            "loss diverged: {s_loss} vs {r_loss}"
        );
    }
    assert!(session.trainer().history.len() >= 3 * STEPS_PER_EVENT);

    // Real migrations happened: shrink events move the departed rank's
    // shard, regrow events restore the newcomer's from the checkpoint.
    let moved: usize = session
        .reports
        .iter()
        .map(|r| r.moved_state_elems)
        .sum();
    assert!(moved > 0, "churn never moved any state");

    // Recurring memberships are cache hits, not DP solves: 5 events
    // over 2 memberships (the size-2 plan is already cached from
    // session start) leaves at most one cold solve.
    assert!(
        session.cache().hits() >= 3,
        "expected ≥3 plan-cache hits, got {} (misses {})",
        session.cache().hits(),
        session.cache().misses()
    );
    assert!(session.reports.iter().any(|r| r.from_cache));
    let cold: usize = session
        .reports
        .iter()
        .filter(|r| !r.from_cache)
        .count();
    assert!(cold <= 1, "more than one cold solve across recurrences");
}

#[test]
fn trace_driven_session_also_matches_the_reference() {
    // Same invariant, but with the membership sizes coming from the
    // AWS availability trace — the actual `elastic --live` path.
    let mut session = session();
    let mut reference = reference();
    let sizes = session.churn_sizes(4);
    assert!(sizes.len() >= 3, "need ≥3 churn events");
    for (hour, &size) in sizes.iter().enumerate() {
        session.step_event(hour, size).unwrap();
        for _ in 0..STEPS_PER_EVENT {
            let idx = reference.history.len();
            reference.step(idx).unwrap();
        }
        assert_eq!(
            session.trainer().params(),
            reference.params(),
            "params diverged after trace hour {hour} (size {size})"
        );
    }
    assert!(session.cache().hits() >= 1);
}
