//! Integration tests over the REAL PJRT runtime: the AOT bridge
//! (HLO text -> parse -> compile -> execute) and the numeric-equivalence
//! invariants of DESIGN.md executed through actual compiled artifacts.
//!
//! Requires `make artifacts`; tests no-op with a loud marker otherwise
//! (CI always builds artifacts first).

// The PJRT runtime only exists behind the `xla` feature (see DESIGN.md
// §Runtime); without it this whole test binary compiles to nothing.
#![cfg(feature = "xla")]

use std::sync::Arc;

use cephalo::runtime::{artifacts_available, default_artifacts_dir,
                       ExecService};
use cephalo::trainer::data::Corpus;
use cephalo::trainer::{init_params, TrainConfig, Trainer, WorkerSpec};
use cephalo::util::prng::Rng;

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("SKIPPED: no artifacts (run `make artifacts`)");
        return true;
    }
    false
}

fn service() -> ExecService {
    ExecService::start(&default_artifacts_dir(), &["grad_step", "loss"])
        .expect("start exec service")
}

fn sample(service: &ExecService, m: usize, seed: u64)
    -> (Vec<i32>, Vec<i32>) {
    let manifest = service.manifest();
    let mut corpus = Corpus::new(manifest.model.vocab, 4, seed);
    corpus.sample_batch(m, manifest.model.seq_len)
}

#[test]
fn loss_at_init_is_near_uniform() {
    if skip() {
        return;
    }
    let svc = service();
    let manifest = svc.manifest().clone();
    let params = Arc::new(init_params(&manifest, 1));
    let (tokens, targets) = sample(&svc, 2, 3);
    let h = svc.handle();
    h.set_params(params).unwrap();
    let (loss_sum, count) = h.loss(tokens, targets, 2).expect("loss exec");
    let mean = loss_sum / count;
    let uniform = (manifest.model.vocab as f32).ln();
    assert!(
        (mean - uniform).abs() < 0.3,
        "init loss {mean} should be ~ln(V) = {uniform}"
    );
}

#[test]
fn gradient_accumulation_equivalence_through_hlo() {
    // DESIGN.md invariant 2, executed on the real artifacts: the sum of
    // two m=1 grad steps equals one m=2 grad step on the same rows.
    if skip() {
        return;
    }
    let svc = service();
    let manifest = svc.manifest().clone();
    let seq = manifest.model.seq_len;
    let params = Arc::new(init_params(&manifest, 1));
    let (tokens, targets) = sample(&svc, 2, 7);
    let h = svc.handle();
    h.set_params(params).unwrap();

    let full = h
        .grad_step(tokens.clone(), targets.clone(), 2)
        .unwrap();
    let a = h
        .grad_step(tokens[..seq].to_vec(), targets[..seq].to_vec(), 1)
        .unwrap();
    let b = h
        .grad_step(tokens[seq..].to_vec(), targets[seq..].to_vec(), 1)
        .unwrap();
    assert!((full.loss_sum - a.loss_sum - b.loss_sum).abs()
        / full.loss_sum.abs()
        < 1e-4);
    for ((gf, ga), gb) in full.grads.iter().zip(&a.grads).zip(&b.grads) {
        for ((f, x), y) in gf.iter().zip(ga).zip(gb) {
            let sum = x + y;
            assert!(
                (f - sum).abs() <= 1e-3 * f.abs().max(1e-2),
                "grad mismatch: {f} vs {sum}"
            );
        }
    }
}

#[test]
fn grad_step_deterministic() {
    if skip() {
        return;
    }
    let svc = service();
    let manifest = svc.manifest().clone();
    let params = Arc::new(init_params(&manifest, 2));
    let (tokens, targets) = sample(&svc, 1, 9);
    let h = svc.handle();
    h.set_params(params).unwrap();
    let g1 = h.grad_step(tokens.clone(), targets.clone(), 1).unwrap();
    let g2 = h.grad_step(tokens, targets, 1).unwrap();
    assert_eq!(g1.loss_sum, g2.loss_sum);
    for (a, b) in g1.grads.iter().zip(&g2.grads) {
        assert_eq!(a, b);
    }
}

#[test]
fn concurrent_grad_steps_from_many_threads() {
    // Worker threads funnel through the exec service; results must be
    // identical to sequential execution.
    if skip() {
        return;
    }
    let svc = service();
    let manifest = svc.manifest().clone();
    let params = Arc::new(init_params(&manifest, 3));
    let (tokens, targets) = sample(&svc, 1, 11);
    let h = svc.handle();
    h.set_params(params).unwrap();
    let expect = h.grad_step(tokens.clone(), targets.clone(), 1).unwrap();
    let results: Vec<f32> = std::thread::scope(|s| {
        (0..4)
            .map(|_| {
                let h = h.clone();
                let tokens = tokens.clone();
                let targets = targets.clone();
                s.spawn(move || {
                    h.grad_step(tokens, targets, 1).unwrap().loss_sum
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|j| j.join().unwrap())
            .collect()
    });
    for r in results {
        assert_eq!(r, expect.loss_sum);
    }
}

#[test]
fn uneven_split_training_matches_single_worker() {
    // DESIGN.md invariant 1 at full-trainer scale: a step with an uneven
    // (3,1) worker split + uneven (0.7, 0.3) state sharding produces the
    // SAME updated parameters as a single worker doing all 4 rows.
    if skip() {
        return;
    }
    let dir = default_artifacts_dir();
    let cfg = TrainConfig {
        steps: 1,
        seed: 5,
        log_every: 0,
        ..Default::default()
    };
    let mut uneven = Trainer::new(
        &dir,
        vec![
            WorkerSpec { batch: 3, state_ratio: 0.7, name: "fast".into() },
            WorkerSpec { batch: 1, state_ratio: 0.3, name: "slow".into() },
        ],
        cfg.clone(),
    )
    .unwrap();
    let mut single = Trainer::new(
        &dir,
        vec![WorkerSpec { batch: 4, state_ratio: 1.0, name: "solo".into() }],
        cfg,
    )
    .unwrap();
    let s1 = uneven.step(0).unwrap();
    let s2 = single.step(0).unwrap();
    assert!((s1.mean_loss - s2.mean_loss).abs() < 1e-5,
            "losses diverge: {} vs {}", s1.mean_loss, s2.mean_loss);
    // Gradients agree to fp32 reduction-order noise, but Adam's step-1
    // update lr*g/(|g|+eps) is chaotic for near-zero gradients (a tiny
    // sign flip moves a parameter by 2*lr). Compare statistically: the
    // bulk of parameters must match tightly, outliers bounded by the
    // 2*lr sign-flip envelope.
    let lr = 3e-4f32; // TrainConfig::default() Adam lr
    let mut n = 0usize;
    let mut sum_abs = 0f64;
    let mut max_abs = 0f32;
    for (a, b) in uneven.params().iter().zip(single.params()) {
        for (x, y) in a.iter().zip(b) {
            let d = (x - y).abs();
            sum_abs += d as f64;
            max_abs = max_abs.max(d);
            n += 1;
        }
    }
    let mean_abs = (sum_abs / n as f64) as f32;
    assert!(
        mean_abs < 0.02 * lr,
        "mean param divergence {mean_abs} vs lr {lr}"
    );
    assert!(
        max_abs <= 2.5 * lr,
        "param divergence {max_abs} beyond the sign-flip envelope"
    );
}

#[test]
fn short_training_run_descends() {
    if skip() {
        return;
    }
    let dir = default_artifacts_dir();
    let cfg = TrainConfig {
        steps: 8,
        seed: 6,
        log_every: 0,
        adam: cephalo::trainer::adam::AdamConfig {
            lr: 2e-3,
            ..Default::default()
        },
        corpus_branch: 4,
        ..Default::default()
    };
    let workers = vec![
        WorkerSpec { batch: 3, state_ratio: 0.5, name: "a".into() },
        WorkerSpec { batch: 2, state_ratio: 0.3, name: "b".into() },
        WorkerSpec { batch: 3, state_ratio: 0.2, name: "c".into() },
    ];
    let mut t = Trainer::new(&dir, workers, cfg).unwrap();
    let hist = t.run().unwrap();
    let first = hist.first().unwrap().mean_loss;
    let last = hist.last().unwrap().mean_loss;
    assert!(
        last < first - 0.05,
        "loss should descend: {first} -> {last}"
    );
    // State bytes split matches ratios.
    let bytes = t.state_bytes_per_worker();
    assert!(bytes[0] > bytes[1] && bytes[1] > bytes[2]);
}

#[test]
fn decomposed_microbatches_match_direct() {
    // batch=3 decomposes into [2, 1]; the summed gradients must equal a
    // hypothetical single pass (checked via loss sums and grad
    // accumulation already proven above — here we exercise the
    // decomposition path end to end).
    if skip() {
        return;
    }
    let svc = service();
    let manifest = svc.manifest().clone();
    assert_eq!(manifest.decompose_batch(3), vec![2, 1]);
    let params = Arc::new(init_params(&manifest, 8));
    let (tokens, targets) = sample(&svc, 3, 13);
    let seq = manifest.model.seq_len;
    let h = svc.handle();
    h.set_params(params).unwrap();
    let g2 = h
        .grad_step(tokens[..2 * seq].to_vec(), targets[..2 * seq].to_vec(),
                   2)
        .unwrap();
    let g1 = h
        .grad_step(tokens[2 * seq..].to_vec(), targets[2 * seq..].to_vec(),
                   1)
        .unwrap();
    let mut rng = Rng::new(0);
    // Spot-check a few hundred random gradient coordinates across the
    // two shards against an m=1+m=1+m=1 decomposition.
    let a = h
        .grad_step(tokens[..seq].to_vec(), targets[..seq].to_vec(), 1)
        .unwrap();
    let b = h
        .grad_step(tokens[seq..2 * seq].to_vec(),
                   targets[seq..2 * seq].to_vec(), 1)
        .unwrap();
    for _ in 0..300 {
        let ti = rng.range(0, g2.grads.len());
        if g2.grads[ti].is_empty() {
            continue;
        }
        let ei = rng.range(0, g2.grads[ti].len());
        let lhs = g2.grads[ti][ei] + g1.grads[ti][ei];
        let rhs = a.grads[ti][ei] + b.grads[ti][ei] + g1.grads[ti][ei];
        assert!((lhs - rhs).abs() <= 1e-3 * lhs.abs().max(1e-2));
    }
}

#[test]
fn checkpoint_resume_across_different_sharding() {
    // Save under a (0.7, 0.3) layout, resume under (0.25 x 4): training
    // continues bit-identically to an uncheckpointed run (same data
    // stream), proving state round-trips through the elastic path.
    if skip() {
        return;
    }
    let dir = default_artifacts_dir();
    let cfg = TrainConfig {
        steps: 2,
        seed: 21,
        log_every: 0,
        ..Default::default()
    };
    let mut a = Trainer::new(
        &dir,
        vec![
            WorkerSpec { batch: 3, state_ratio: 0.7, name: "a".into() },
            WorkerSpec { batch: 1, state_ratio: 0.3, name: "b".into() },
        ],
        cfg.clone(),
    )
    .unwrap();
    a.step(0).unwrap();
    let ck = a.checkpoint();
    assert_eq!(ck.step, 1);
    let tmp = std::env::temp_dir().join("ceph_resume.ckpt");
    ck.save(&tmp).unwrap();
    let loaded =
        cephalo::trainer::checkpoint::Checkpoint::load(&tmp).unwrap();

    // Continue on A (reference trajectory).
    let sa = a.step(1).unwrap();

    // Fresh trainer with a DIFFERENT shard layout; restore; same data
    // stream state requires same corpus position -> replay step 0's
    // batch by stepping once before restore.
    let mut b = Trainer::new(
        &dir,
        vec![
            WorkerSpec { batch: 1, state_ratio: 0.25, name: "w0".into() },
            WorkerSpec { batch: 1, state_ratio: 0.25, name: "w1".into() },
            WorkerSpec { batch: 1, state_ratio: 0.25, name: "w2".into() },
            WorkerSpec { batch: 1, state_ratio: 0.25, name: "w3".into() },
        ],
        cfg,
    )
    .unwrap();
    b.step(0).unwrap(); // advance the corpus to the same position
    b.restore(&loaded).unwrap();
    let sb = b.step(1).unwrap();
    assert!(
        (sa.mean_loss - sb.mean_loss).abs() < 1e-5,
        "resumed trajectory diverged: {} vs {}",
        sa.mean_loss,
        sb.mean_loss
    );
}
