//! Fig. 2: GPU FP32 TFLOPs vs memory capacity — the compute/memory
//! mismatch motivating Cephalo (e.g. L4 ~2.6x the compute of the P40 at
//! identical 24 GB memory).

use cephalo::cluster::catalog::{catalog, find};
use cephalo::util::tablefmt::Table;

fn main() {
    let mut t = Table::new(
        "Fig. 2 — GPU TFLOPs (FP32) vs memory capacity",
        &["gpu", "generation", "TFLOPs", "memory GB", "TFLOPs/GB"],
    );
    let mut gpus = catalog();
    gpus.sort_by(|a, b| {
        b.compute_mem_ratio().partial_cmp(&a.compute_mem_ratio()).unwrap()
    });
    for g in &gpus {
        t.add_row(vec![
            g.name.clone(),
            g.generation.clone(),
            format!("{:.1}", g.tflops_fp32),
            format!("{:.0}", g.mem_gb),
            format!("{:.2}", g.compute_mem_ratio()),
        ]);
    }
    println!("{}", t.render());

    // ASCII scatter: memory (x) vs tflops (y).
    println!("scatter (x = memory GB, y = TFLOPs):");
    let max_t = gpus.iter().map(|g| g.tflops_fp32).fold(0.0, f64::max);
    for row in (0..12).rev() {
        let lo = max_t * row as f64 / 12.0;
        let hi = max_t * (row + 1) as f64 / 12.0;
        let mut line = format!("{:>5.0} |", hi);
        for col in 0..20 {
            let mlo = 80.0 * col as f64 / 20.0;
            let mhi = 80.0 * (col + 1) as f64 / 20.0;
            let hit = gpus.iter().find(|g| {
                g.tflops_fp32 > lo
                    && g.tflops_fp32 <= hi
                    && g.mem_gb > mlo
                    && g.mem_gb <= mhi
            });
            line.push_str(match hit {
                Some(g) => match g.name.as_str() {
                    "L4" => "L",
                    "P40" => "P",
                    "A6000" => "A",
                    "H100" => "H",
                    _ => "*",
                },
                None => " ",
            });
        }
        println!("{line}");
    }
    println!("      +{}", "-".repeat(20));
    println!("       0        40        80  (GB)");

    // The motivating pair.
    let l4 = find("L4").unwrap();
    let p40 = find("P40").unwrap();
    assert_eq!(l4.mem_gb, p40.mem_gb);
    assert!(l4.tflops_fp32 > 2.0 * p40.tflops_fp32);
    println!(
        "\nshape check: L4 ({:.1} TF) vs P40 ({:.1} TF) at equal {} GB [ok]",
        l4.tflops_fp32, p40.tflops_fp32, l4.mem_gb
    );
}
