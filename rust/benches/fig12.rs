//! Fig. 12 (Supplementary C): NCCL collective latency for uneven vs
//! even input sizes — (top) latency vs collective size, (bottom)
//! latency vs input skew at fixed size. Two layers of evidence here:
//!
//! 1. the analytic cost model used by the optimizer (latency tracks
//!    collective size; uneven = +15% independent of skew), and
//! 2. REAL numeric ring collectives (`collectives::ring_*`) timed at
//!    varying skew, asserting that wall-clock is governed by total
//!    size, not skew — the paper's observation 2.

use cephalo::benchkit::Bencher;
use cephalo::cluster::Cluster;
use cephalo::perfmodel::collective::{input_skew, CollectiveModel};
use cephalo::sharding::ShardLayout;
use cephalo::testkit::Gen;
use cephalo::util::tablefmt::Table;

fn main() {
    let model = CollectiveModel::from_cluster(&Cluster::cluster_a());

    // Top: latency vs collective size.
    let mut t = Table::new(
        "Fig. 12 top — modeled collective latency vs size (Cluster A ring)",
        &["size MB", "AllGather even (ms)", "AllGather uneven (ms)",
          "ReduceScatter even (ms)", "ReduceScatter uneven (ms)"],
    );
    for mb in [8u64, 16, 32, 64, 128, 256, 512] {
        let bytes = (mb * 1024 * 1024) as f64;
        t.add_row(vec![
            mb.to_string(),
            format!("{:.2}", model.allgather(bytes) * 1e3),
            format!("{:.2}", model.allgather_uneven(bytes) * 1e3),
            format!("{:.2}", model.reduce_scatter(bytes) * 1e3),
            format!("{:.2}", model.reduce_scatter_uneven(bytes) * 1e3),
        ]);
    }
    println!("{}", t.render());

    // Bottom: REAL ring collectives at fixed total size, varying skew.
    let n = 8usize;
    let len = 1 << 20; // 1M f32 = 4 MB collective
    let mut g = Gen::new(0xF16, 1.0);
    let contributions: Vec<Vec<f32>> =
        (0..n).map(|_| g.vec_f32(len, 1.0)).collect();

    let layouts: Vec<(String, ShardLayout)> = vec![
        ("even (skew 0.125)".into(), ShardLayout::even(len, n)),
        (
            "mild (skew ~0.25)".into(),
            ShardLayout::by_ratios(
                len,
                &[2.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
            ),
        ),
        (
            "strong (skew ~0.5)".into(),
            ShardLayout::by_ratios(
                len,
                &[7.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
            ),
        ),
        (
            "extreme (skew ~0.9)".into(),
            ShardLayout::by_ratios(
                len,
                &[63.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0],
            ),
        ),
    ];
    let mut b = Bencher::new(2, 6);
    println!("Fig. 12 bottom — REAL ring collectives, 4 MB total, varying \
              skew:");
    // Pre-build shards per layout.
    let shard_sets: Vec<Vec<Vec<f32>>> = layouts
        .iter()
        .map(|(_, layout)| {
            (0..n)
                .map(|r| contributions[r][layout.range(r)].to_vec())
                .collect()
        })
        .collect();
    // Interleave measurement ROUNDS across layouts so slow drift on this
    // shared single core (thermal, background tests) hits every layout
    // equally; keep the min over rounds (the intrinsic data-movement
    // cost the figure is about).
    let mut times: Vec<(f64, f64)> = layouts
        .iter()
        .map(|(_, layout)| {
            let sizes: Vec<f64> =
                layout.sizes().iter().map(|&s| s as f64).collect();
            (input_skew(&sizes), f64::INFINITY)
        })
        .collect();
    for round in 0..3 {
        for (i, (name, layout)) in layouts.iter().enumerate() {
            let m = b.bench(
                &format!("ring_allgather {name} (round {round})"),
                || cephalo::collectives::ring_allgather(&shard_sets[i],
                                                        layout),
            );
            times[i].1 = times[i].1.min(m.min_s);
        }
    }
    for (name, layout) in &layouts {
        b.bench(&format!("ring_reduce_scatter {name}"), || {
            cephalo::collectives::ring_reduce_scatter(&contributions, layout)
        });
    }

    // Observation 2: latency stays within a narrow band across skews.
    let mins: Vec<f64> = times.iter().map(|(_, t)| *t).collect();
    let min = cephalo::util::stats::min(&mins);
    let max = cephalo::util::stats::max(&mins);
    println!(
        "\nskew sweep min-sample range: {:.3} .. {:.3} ms (ratio {:.2})",
        min * 1e3,
        max * 1e3,
        max / min
    );
    assert!(
        max / min < 2.0,
        "latency should be governed by size, not skew (got {:.2}x)",
        max / min
    );
    println!("shape check: latency ~ size, weak skew dependence  [ok]");
}
