//! Param-shard memory bench: per-rank parameter/state bytes under
//! leader-resident vs fully-sharded residency — the tentpole's memory
//! claim measured at both scales:
//!
//! * PLANNING scale: the Table-2 model's accounting on a real DP
//!   assignment (`memory::ParamResidency`), per GPU;
//! * EXECUTED scale: live native trainers in both residencies, with
//!   the measured resident weight bytes per rank and steps/sec (the
//!   head-of-step gather replaces the tail AllGather, so throughput
//!   should be within noise).
//!
//! `--quick` shrinks the run for CI smoke; `--json <path>` writes the
//! tables as a JSON artifact — the seed for a perf-trajectory gate.

use std::collections::BTreeMap;
use std::time::Instant;

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::exec::{NativeExecutor, SurrogateSpec};
use cephalo::memory::ParamResidency;
use cephalo::trainer::{TrainConfig, Trainer, WorkerSpec};
use cephalo::util::json::Json;
use cephalo::util::tablefmt::Table;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn main() {
    let (quick, json_path) = cephalo::benchkit::bench_args();
    let mut json_rows: Vec<Json> = Vec::new();

    // ---- Planning scale: cluster A, BERT-Large, the DP's division ----
    let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
        .expect("workload");
    let (asg, _) = w.optimize(64).expect("solvable");
    let total = w.profile.total_params;
    let mut t = Table::new(
        "Per-GPU parameter/state bytes (GB): leader-resident vs \
         fully-sharded, BERT-Large on cluster A @ 64",
        &["gpu", "r_i", "params leader", "params sharded",
          "state leader", "state sharded"],
    );
    for (i, g) in asg.per_gpu.iter().enumerate() {
        let (ld, sh) =
            (ParamResidency::LeaderResident, ParamResidency::FullySharded);
        t.add_row(vec![
            i.to_string(),
            format!("{:.3}", g.state_ratio),
            format!("{:.3}", ld.param_bytes(total, g.state_ratio) / 1e9),
            format!("{:.3}", sh.param_bytes(total, g.state_ratio) / 1e9),
            format!(
                "{:.3}",
                ld.per_gpu_state_bytes(total, g.state_ratio) / 1e9
            ),
            format!(
                "{:.3}",
                sh.per_gpu_state_bytes(total, g.state_ratio) / 1e9
            ),
        ]);
        let mut row = BTreeMap::new();
        row.insert("scale".into(), Json::Str("planning".into()));
        row.insert("gpu".into(), num(i as f64));
        row.insert("state_ratio".into(), num(g.state_ratio));
        row.insert(
            "param_bytes_leader".into(),
            num(ParamResidency::LeaderResident
                .param_bytes(total, g.state_ratio)),
        );
        row.insert(
            "param_bytes_sharded".into(),
            num(ParamResidency::FullySharded
                .param_bytes(total, g.state_ratio)),
        );
        json_rows.push(Json::Obj(row));
    }
    println!("{}", t.render());

    // ---- Executed scale: live trainers in both residencies ----
    // Quick-mode steps/s feeds the cross-run perf gate (rate noise
    // band 0.25): 12 steps amortizes trainer warm-up and scheduler
    // hiccups enough to sit inside the tightened band (3 did not).
    let steps = if quick { 12 } else { 20 };
    let workers = || {
        vec![
            WorkerSpec { batch: 3, state_ratio: 0.6, name: "big".into() },
            WorkerSpec { batch: 3, state_ratio: 0.3, name: "mid".into() },
            WorkerSpec { batch: 2, state_ratio: 0.1, name: "small".into() },
        ]
    };
    let bench = |shard_params: bool| -> (Vec<usize>, f64) {
        let cfg = TrainConfig {
            steps: 0,
            seed: 7,
            log_every: 0,
            shard_params,
            ..Default::default()
        };
        let mut tr = Trainer::from_executor(
            Box::new(NativeExecutor::new(SurrogateSpec::default())),
            workers(),
            cfg,
        )
        .expect("trainer");
        let t0 = Instant::now();
        for s in 0..steps {
            tr.step(s).expect("step");
        }
        let sps = steps as f64 / t0.elapsed().as_secs_f64();
        (tr.param_bytes_per_worker(), sps)
    };
    let (leader_bytes, leader_sps) = bench(false);
    let (sharded_bytes, sharded_sps) = bench(true);
    let mut t = Table::new(
        &format!(
            "Measured resident weight bytes per rank (native surrogate, \
             {steps} steps)"
        ),
        &["residency", "rank 0", "rank 1", "rank 2", "steps/s"],
    );
    for (label, bytes, sps) in [
        ("leader", &leader_bytes, leader_sps),
        ("sharded", &sharded_bytes, sharded_sps),
    ] {
        t.add_row(vec![
            label.to_string(),
            bytes[0].to_string(),
            bytes[1].to_string(),
            bytes[2].to_string(),
            format!("{sps:.1}"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("scale".into(), Json::Str("executed".into()));
        row.insert("residency".into(), Json::Str(label.into()));
        row.insert(
            "param_bytes".into(),
            Json::Arr(bytes.iter().map(|&b| num(b as f64)).collect()),
        );
        row.insert("steps_per_sec".into(), num(sps));
        json_rows.push(Json::Obj(row));
    }
    println!("{}", t.render());

    // Shape checks: sharded bytes partition the total; leader bytes
    // replicate it on every rank.
    let total_bytes: usize = sharded_bytes.iter().sum();
    assert_eq!(total_bytes, leader_bytes[0]);
    assert!(leader_bytes.iter().all(|&b| b == leader_bytes[0]));
    assert!(sharded_bytes[0] > sharded_bytes[2]);
    println!(
        "shape check: sharded ranks partition {total_bytes} weight \
         bytes; every leader rank replicates them  [ok]"
    );

    // ---- Transient gather peak: whole-model vs FSDP units ----
    // The FSDP-unit claim, measured: the per-rank peak of TRANSIENTLY
    // materialized parameter bytes scales with the largest unit (plus
    // the double-buffered prefetch and the bias tail), not with total
    // parameters.
    let units: usize = cephalo::benchkit::bench_opt("fsdp-units")
        .map(|s| s.parse().expect("bad --fsdp-units"))
        .unwrap_or(4);
    let peak_bench = |fsdp_units: usize| -> (usize, usize, usize) {
        let cfg = TrainConfig {
            steps: 0,
            seed: 7,
            log_every: 0,
            shard_params: true,
            fsdp_units,
            ..Default::default()
        };
        let mut tr = Trainer::from_executor(
            Box::new(NativeExecutor::new(SurrogateSpec::default())),
            workers(),
            cfg,
        )
        .expect("trainer");
        for s in 0..steps {
            tr.step(s).expect("step");
        }
        let ul = tr.units();
        let tail = ul.unit_len(ul.num_units() - 1);
        (
            tr.peak_materialized_elems() * 4,
            ul.largest_unit() * 4,
            tail * 4,
        )
    };
    let (whole_peak, _, _) = peak_bench(1);
    let (unit_peak, largest_bytes, tail_bytes) = peak_bench(units);
    let mut t = Table::new(
        &format!(
            "Per-rank transient gather peak (bytes): whole-model vs \
             {units} FSDP units"
        ),
        &["gather", "peak bytes", "largest unit", "bound (2u + tail)"],
    );
    t.add_row(vec![
        "whole".into(),
        whole_peak.to_string(),
        "-".into(),
        "-".into(),
    ]);
    t.add_row(vec![
        format!("{units} units"),
        unit_peak.to_string(),
        largest_bytes.to_string(),
        (2 * largest_bytes + tail_bytes).to_string(),
    ]);
    println!("{}", t.render());
    for (fsdp_units, peak, largest, tail) in [
        (1usize, whole_peak, whole_peak, 0usize),
        (units, unit_peak, largest_bytes, tail_bytes),
    ] {
        let mut row = BTreeMap::new();
        row.insert("scale".into(), Json::Str("transient".into()));
        row.insert("fsdp_units".into(), num(fsdp_units as f64));
        row.insert("peak_param_bytes".into(), num(peak as f64));
        row.insert("largest_unit_bytes".into(), num(largest as f64));
        row.insert("tail_bytes".into(), num(tail as f64));
        json_rows.push(Json::Obj(row));
    }
    // Whole-model gather materializes every weight byte; the unit
    // schedule's peak is bounded by the prefetch pair + tail, strictly
    // below the model.
    assert_eq!(whole_peak, total_bytes);
    assert!(unit_peak <= 2 * largest_bytes + tail_bytes);
    assert!(unit_peak < whole_peak);
    println!(
        "shape check: {units}-unit peak {unit_peak} B scales with the \
         largest unit ({largest_bytes} B), not the model \
         ({whole_peak} B)  [ok]"
    );

    if let Some(path) = json_path {
        cephalo::benchkit::write_json_rows(
            &path, "param_shard_mem", quick, json_rows,
        );
    }
}
