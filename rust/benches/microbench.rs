//! Hot-path microbenchmarks — the §Perf instrumentation for L3.
//! Covers: DP optimizer solve, greedy state partition, the event
//! simulator, shard planning, numeric collectives, and (when artifacts
//! are present) the real PJRT grad step.

use cephalo::benchkit::Bencher;
use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::optimizer::{partition_state, DpOptimizer};
use cephalo::plan::{sweep, PlanCache, PlannerRegistry};
use cephalo::sharding::{ShardLayout, ShardPlan};
use cephalo::sim::GaVariant;
use cephalo::testkit::Gen;

fn main() {
    let mut b = Bencher::new(2, 10);

    // --- optimizer ---
    let wa = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
        .unwrap();
    b.bench("dp_solve cluster A, B=128", || {
        DpOptimizer::default().solve(&wa.profile, 128).unwrap()
    });
    b.bench("dp_solve cluster A, B=256", || {
        DpOptimizer::default().solve(&wa.profile, 256).unwrap()
    });
    let wb = Workload::prepare(Cluster::cluster_b(), "GPT 6.7B", 42)
        .unwrap();
    let mut b_slow = Bencher::new(1, 3);
    b_slow.bench("dp_solve cluster B (64 GPUs), B=512", || {
        DpOptimizer::default().solve(&wb.profile, 512).unwrap()
    });
    b_slow.bench("dp_solve cluster B (64 GPUs), B=1024", || {
        DpOptimizer::default().solve(&wb.profile, 1024).unwrap()
    });

    let (asg_a, _) = DpOptimizer::default().solve(&wa.profile, 128).unwrap();
    b.bench("greedy_state_partition (8 GPUs)", || {
        let mut pg = asg_a.per_gpu.clone();
        partition_state(&wa.profile, &mut pg).unwrap();
        pg
    });

    // --- simulator ---
    b.bench("simulate_iteration BERT-Large/A (24 units)", || {
        wa.simulate(&asg_a, GaVariant::LGA_CO_S_O)
    });
    let (asg_b, _) = DpOptimizer::default().solve(&wb.profile, 512).unwrap();
    b.bench("simulate_iteration GPT-6.7B/B (64 GPUs, 32 units)", || {
        wb.simulate(&asg_b, GaVariant::LGA_CO_S_O)
    });

    // --- sharding + collectives ---
    b.bench("shard_plan 48 units x 8 GPUs", || {
        ShardPlan::plan(48, 33_000_000, &[0.3, 0.2, 0.15, 0.1, 0.1, 0.05,
                                          0.05, 0.05])
    });
    let mut g = Gen::new(1, 1.0);
    let len = 1 << 20;
    let layout = ShardLayout::by_ratios(len, &[0.3, 0.3, 0.2, 0.2]);
    let full: Vec<Vec<f32>> = (0..4).map(|_| g.vec_f32(len, 1.0)).collect();
    let shards: Vec<Vec<f32>> =
        (0..4).map(|r| full[r][layout.range(r)].to_vec()).collect();
    b.bench("ring_allgather 4 MB x 4 ranks", || {
        cephalo::collectives::ring_allgather(&shards, &layout)
    });
    b.bench("ring_reduce_scatter 4 MB x 4 ranks", || {
        cephalo::collectives::ring_reduce_scatter(&full, &layout)
    });

    // --- plan subsystem: registry sweep + cache ---
    let registry = PlannerRegistry::with_defaults();
    b.bench("plan sweep: 9 planners x B=128, cluster A (parallel)", || {
        sweep(&wa.ctx(0), registry.planners(), &[128], None)
    });
    let cache = PlanCache::new();
    let cephalo_planner = registry.get("cephalo").unwrap();
    cache.get_or_plan(&*cephalo_planner, &wa.ctx(128)).unwrap();
    b.bench("plan_cache hit: cephalo/A B=128 (elastic fast path)", || {
        cache.get_or_plan(&*cephalo_planner, &wa.ctx(128)).unwrap()
    });
    b.bench("plan fingerprint: cluster A profile", || {
        cephalo::plan::fingerprint(&wa.cluster, &wa.profile)
    });

    // --- real PJRT grad step (optional, xla builds only) ---
    pjrt_bench();
    println!("\nmicrobench done");
}

#[cfg(feature = "xla")]
fn pjrt_bench() {
    let dir = cephalo::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        match cephalo::runtime::ExecService::start(&dir, &["grad_step"]) {
            Ok(service) => {
                let manifest = service.manifest().clone();
                let params = std::sync::Arc::new(
                    cephalo::trainer::init_params(&manifest, 7),
                );
                let handle = service.handle();
                handle.set_params(std::sync::Arc::clone(&params)).unwrap();
                let seq = manifest.model.seq_len;
                let vocab = manifest.model.vocab as i64;
                let mut rng = cephalo::util::prng::Rng::new(3);
                for &m in &manifest.microbatches.clone() {
                    let tokens: Vec<i32> = (0..m * seq)
                        .map(|_| rng.range_i64(0, vocab) as i32)
                        .collect();
                    let targets = tokens.clone();
                    let mut bm = Bencher::new(1, 5);
                    bm.bench(&format!("pjrt grad_step m={m}"), || {
                        handle
                            .grad_step(tokens.clone(), targets.clone(), m)
                            .unwrap()
                    });
                }
            }
            Err(e) => println!("pjrt microbench skipped: {e}"),
        }
    } else {
        println!("pjrt microbench skipped: no artifacts");
    }
}

#[cfg(not(feature = "xla"))]
fn pjrt_bench() {
    println!("pjrt microbench skipped: built without the `xla` feature");
}
