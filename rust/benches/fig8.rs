//! Fig. 8: speedup and memory reduction from the gradient-accumulation
//! optimizations, GPT 6.7B on a homogeneous 16xV100 cluster (2x
//! p3.16xlarge, 25 Gbps NICs), batch 256 = 16 microbatches of size 1
//! per GPU. Ladder: FSDP-GA -> LGA -> +CO -> +S -> +O.

use cephalo::cluster::Cluster;
use cephalo::model::find_model;
use cephalo::optimizer::{Assignment, GpuAssign};
use cephalo::perfmodel::{CollectiveModel, SyntheticOracle};
use cephalo::sim::cephalo::simulate_assignment;
use cephalo::sim::GaVariant;
use cephalo::util::tablefmt::Table;

fn main() {
    let cluster = Cluster::preset("16xv100").unwrap();
    let model = find_model("GPT 6.7B").unwrap();
    let oracle = SyntheticOracle::new(&cluster, &model, 42);
    let coll = CollectiveModel::from_cluster(&cluster);
    let asg = Assignment {
        per_gpu: (0..16)
            .map(|_| GpuAssign {
                microbatch: 1,
                num_micro: 16,
                state_ratio: 1.0 / 16.0,
            })
            .collect(),
        layer_latency: 0.0,
        iter_latency: 0.0,
    };

    let ladder = [
        ("FSDP-GA", GaVariant::FSDP_GA),
        ("LGA", GaVariant::LGA),
        ("LGA+CO", GaVariant::LGA_CO),
        ("LGA+CO+S", GaVariant::LGA_CO_S),
        ("LGA+CO+S+O", GaVariant::LGA_CO_S_O),
    ];
    let base = simulate_assignment(&model, &oracle, &coll, &asg,
                                   GaVariant::FSDP_GA);
    let mut t = Table::new(
        "Fig. 8 — GA optimizations (GPT 6.7B, 16xV100, batch 256)",
        &["variant", "iter (s)", "samples/s", "speedup", "AllGathers",
          "peak mem GB"],
    );
    let mut speedups = Vec::new();
    let mut mems = Vec::new();
    for (name, v) in ladder {
        let s = simulate_assignment(&model, &oracle, &coll, &asg, v);
        let peak = s.per_gpu_mem.iter().fold(0.0f64, |a, &b| a.max(b));
        speedups.push(base.latency / s.latency);
        mems.push(peak);
        t.add_row(vec![
            name.into(),
            format!("{:.2}", s.latency),
            format!("{:.2}", s.throughput),
            format!("{:.2}x", base.latency / s.latency),
            s.ag_count.to_string(),
            format!("{:.1}", peak / 1e9),
        ]);
    }
    println!("{}", t.render());

    // Shape: monotone ladder; LGA's big jump comes from the 16x fewer
    // AllGathers (paper: 6x there, 7.8x total; our simulated substrate
    // lands lower but the ordering and the memory story must hold).
    assert!(
        speedups.windows(2).all(|w| w[1] >= w[0] * 0.999),
        "ladder not monotone: {speedups:?}"
    );
    assert!(speedups[1] > 1.5, "LGA speedup too small: {}", speedups[1]);
    assert!(speedups[4] > speedups[1], "CO+S+O must add on top of LGA");
    // Memory: +O reduces below FSDP-GA; LGA alone raises it.
    assert!(mems[1] > mems[0], "LGA should raise memory");
    assert!(mems[4] < mems[0], "full ladder should cut memory");
    println!(
        "shape check: monotone {:.2}x..{:.2}x, mem {:.1} -> {:.1} GB  [ok]",
        speedups[0], speedups[4], mems[0] / 1e9, mems[4] / 1e9
    );
}
