//! Fig. 10: performance-model accuracy — absolute relative error
//! between the optimizer's predicted iteration latency (Eqs. 2/3 over
//! the fitted linear models) and the "actual" latency from the event
//! simulator driven by the ground-truth oracle. Paper: all errors
//! within 10%, mean ARE 2.9%.

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::sim::GaVariant;
use cephalo::util::tablefmt::Table;

fn main() {
    let models = [
        "ViT-G", "ViT-e", "BERT-Large", "BERT-XLarge", "GPT 2.7B",
        "Tiny Llama", "Llama 3B",
    ];
    let batches = [64usize, 128, 256];
    let mut t = Table::new(
        "Fig. 10 — performance model absolute relative error (Cluster A)",
        &["model", "batch", "predicted (s)", "actual (s)", "ARE %"],
    );
    let mut errors = Vec::new();
    for model in models {
        let w = Workload::prepare(Cluster::cluster_a(), model, 42)
            .expect("profile");
        for &batch in &batches {
            let Ok((asg, _)) = w.optimize(batch) else { continue };
            let stats = w.simulate(&asg, GaVariant::LGA_CO_S_O);
            let are = (asg.iter_latency - stats.latency).abs()
                / stats.latency;
            errors.push(are);
            t.add_row(vec![
                model.into(),
                batch.to_string(),
                format!("{:.3}", asg.iter_latency),
                format!("{:.3}", stats.latency),
                format!("{:.2}", are * 100.0),
            ]);
        }
    }
    println!("{}", t.render());
    let mean = cephalo::util::stats::mean(&errors);
    let max = cephalo::util::stats::max(&errors);
    println!(
        "mean ARE {:.2}%  max ARE {:.2}%  ({} configurations)",
        mean * 100.0,
        max * 100.0,
        errors.len()
    );
    assert!(max < 0.10, "max ARE {max:.3} exceeds the paper's 10% bound");
    assert!(mean < 0.05, "mean ARE {mean:.3} too high (paper: 2.9%)");
    println!("shape check: errors within 10%, mean under 5%  [ok]");
}
