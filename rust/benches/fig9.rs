//! Fig. 9: optimized training configurations for ViT-G and Llama 3B on
//! Cluster A at batch 256 — per-GPU batch share and training-state
//! share. Expected shape (§4.6): the A6000 takes the largest batch AND
//! the largest state share; L4s about half of the A6000; P40s hold more
//! state than P100s thanks to their 24 GB.

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::util::tablefmt::Table;

fn main() {
    for model in ["ViT-G", "Llama 3B"] {
        let w = Workload::prepare(Cluster::cluster_a(), model, 42)
            .expect("profile");
        let (asg, _) = w.optimize(256).expect("plan");
        let mut t = Table::new(
            &format!("Fig. 9 — optimized configuration: {model}, \
                      Cluster A, batch 256"),
            &["gpu", "type", "batch b_i", "batch %", "micro m_i x l_i",
              "state %"],
        );
        let gpus = w.cluster.gpus();
        for (i, (g, slot)) in asg.per_gpu.iter().zip(&gpus).enumerate() {
            t.add_row(vec![
                i.to_string(),
                slot.spec.name.clone(),
                g.batch().to_string(),
                format!("{:.1}", g.batch() as f64 / 256.0 * 100.0),
                format!("{} x {}", g.microbatch, g.num_micro),
                format!("{:.1}", g.state_ratio * 100.0),
            ]);
        }
        println!("{}", t.render());

        // Shape checks (§4.6).
        let by_type = |name: &str| -> (f64, f64) {
            let mut batch = 0usize;
            let mut state = 0.0;
            let mut n = 0usize;
            for (g, slot) in asg.per_gpu.iter().zip(&gpus) {
                if slot.spec.name == name {
                    batch += g.batch();
                    state += g.state_ratio;
                    n += 1;
                }
            }
            (batch as f64 / n as f64, state / n as f64)
        };
        let (a6000_b, a6000_s) = by_type("A6000");
        let (l4_b, _) = by_type("L4");
        let (p40_b, p40_s) = by_type("P40");
        let (p100_b, p100_s) = by_type("P100");
        assert!(a6000_b >= l4_b, "{model}: A6000 should lead batch");
        assert!(a6000_s >= p40_s, "{model}: A6000 should lead state");
        assert!(
            p40_s > p100_s,
            "{model}: P40 (24 GB) should hold more state than P100 (12 GB)"
        );
        assert!(
            l4_b > p40_b.max(p100_b),
            "{model}: L4 should out-batch Pascal GPUs"
        );
        println!("shape check [{model}]: A6000 leads, P40>P100 state  \
                  [ok]\n");
    }
}
