//! Fig. 5: single-layer training latency and compute memory vs
//! microbatch size (BERT-Large) — sublinear latency at small m, then
//! linear; memory strongly linear.
//!
//! Two series: the synthetic oracle ("profiled") against the fitted
//! linear models the optimizer actually plans with; plus, when AOT
//! artifacts exist, a REAL PJRT series timing the compiled layer
//! forward on this host.

use cephalo::cluster::Cluster;
use cephalo::model::find_model;
use cephalo::perfmodel::{ComputeOracle, Profiler, SyntheticOracle};
use cephalo::util::tablefmt::Table;

fn main() {
    let cluster = Cluster::cluster_a();
    let model = find_model("BERT-Large").unwrap();
    let oracle = SyntheticOracle::new(&cluster, &model, 42);
    let profile = Profiler::default().profile(&cluster, &model, &oracle);
    let gpu = 2; // the A6000

    let mut t = Table::new(
        "Fig. 5 — BERT-Large layer latency & compute memory vs microbatch \
         (A6000 slot)",
        &["m", "latency profiled (ms)", "latency fitted (ms)",
          "per-sample (ms)", "mem profiled (GB)", "mem fitted (GB)"],
    );
    for m in [1usize, 2, 3, 4, 6, 8, 12, 16, 24, 32] {
        let lat = oracle.fwd_latency(gpu, m) + oracle.bwd_latency(gpu, m);
        let fit = profile.per_gpu[gpu].fwd.predict(m)
            + profile.per_gpu[gpu].bwd.predict(m);
        let mem = oracle.compute_mem(gpu, m);
        let mem_fit = profile.per_gpu[gpu].mem.predict(m);
        t.add_row(vec![
            m.to_string(),
            format!("{:.1}", lat * 1e3),
            format!("{:.1}", fit * 1e3),
            format!("{:.2}", lat * 1e3 / m as f64),
            format!("{:.2}", mem / 1e9),
            format!("{:.2}", mem_fit / 1e9),
        ]);
    }
    println!("{}", t.render());

    // Shape: per-sample latency improves with m (sublinear start)...
    let per1 = oracle.fwd_latency(gpu, 1);
    let per8 = oracle.fwd_latency(gpu, 8) / 8.0;
    assert!(per1 > 1.2 * per8, "no sublinear regime");
    // ...and memory is linear (R^2 of the fit near 1).
    let pts: Vec<(f64, f64)> = (1..=8)
        .map(|m| (m as f64, oracle.compute_mem(gpu, m)))
        .collect();
    let (slope, icpt) = cephalo::util::stats::linear_fit(&pts);
    let r2 = cephalo::util::stats::r_squared(&pts, slope, icpt);
    assert!(r2 > 0.98, "memory not linear: r2={r2}");
    println!("shape check: sublinear latency + linear memory (r2={r2:.4}) \
              [ok]");

    // Real PJRT series (artifacts present only after `make artifacts`;
    // xla builds only).
    real_series();
}

#[cfg(feature = "xla")]
fn real_series() {
    let dir = cephalo::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        match cephalo::coordinator::real_profile::profile_layer_fwd(&dir, 5)
        {
            Ok(samples) => {
                let mut rt = Table::new(
                    "Fig. 5 (real) — AOT layer_fwd via PJRT on this host",
                    &["m", "mean", "min", "per-sample"],
                );
                for s in &samples {
                    rt.add_row(vec![
                        s.microbatch.to_string(),
                        cephalo::util::human_secs(s.mean_seconds),
                        cephalo::util::human_secs(s.min_seconds),
                        cephalo::util::human_secs(
                            s.mean_seconds / s.microbatch as f64,
                        ),
                    ]);
                }
                println!("{}", rt.render());
            }
            Err(e) => println!("real profile skipped: {e}"),
        }
    } else {
        println!("real profile skipped: no artifacts (run `make artifacts`)");
    }
}

#[cfg(not(feature = "xla"))]
fn real_series() {
    println!("real profile skipped: built without the `xla` feature");
}
