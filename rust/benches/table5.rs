//! Table 5: throughput on the 64-GPU Cluster B — ViT-e / GPT 6.7B /
//! Llama 7B at batch {512, 1024} x {Megatron-Het, FlashFlex, Cephalo}.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{cell, throughput, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::util::tablefmt::Table;

fn main() {
    let models = ["ViT-e", "GPT 6.7B", "Llama 7B"];
    let systems = [
        SystemKind::MegatronHet,
        SystemKind::FlashFlex,
        SystemKind::Cephalo,
    ];
    let mut headers = vec!["System".to_string()];
    for m in models {
        headers.push(format!("{m} @512"));
        headers.push(format!("{m} @1024"));
    }
    let mut t = Table::new(
        "Table 5 — throughput (samples/s), Cluster B (64 GPUs)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    let workloads: Vec<Workload> = models
        .iter()
        .map(|m| {
            Workload::prepare(Cluster::cluster_b(), m, 42).expect("profile")
        })
        .collect();
    for system in systems {
        let mut row = vec![system.name().to_string()];
        for w in &workloads {
            row.push(cell(w, 512, system));
            row.push(cell(w, 1024, system));
        }
        t.add_row(row);
    }
    println!("{}", t.render());

    // Shape: Cephalo clearly ahead of the best baseline (§4.3: 2-10x).
    for (i, w) in workloads.iter().enumerate() {
        for batch in [512usize, 1024] {
            let c = throughput(w, batch, SystemKind::Cephalo)
                .unwrap_or_else(|e| {
                    panic!("Cephalo OOM on {} @{batch}: {e}", models[i])
                });
            let best_baseline = [SystemKind::MegatronHet,
                                 SystemKind::FlashFlex]
                .iter()
                .filter_map(|s| throughput(w, batch, *s).ok())
                .fold(0.0f64, f64::max);
            if best_baseline > 0.0 {
                let ratio = c / best_baseline;
                assert!(
                    ratio > 1.2,
                    "{}: Cephalo speedup only {ratio:.2}x @{batch}",
                    models[i]
                );
                println!(
                    "{} @{batch}: Cephalo {c:.2}, best baseline \
                     {best_baseline:.2} ({ratio:.1}x)",
                    models[i]
                );
            }
        }
    }
}
