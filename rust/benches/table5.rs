//! Table 5: throughput on the 64-GPU Cluster B — ViT-e / GPT 6.7B /
//! Llama 7B at batch {512, 1024} x {Megatron-Het, FlashFlex, Cephalo},
//! via one parallel `plan::sweep` per workload.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{find_cell, outcome_cell, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::plan::{sweep, PlannerRegistry, SweepCell};
use cephalo::util::tablefmt::Table;

fn main() {
    let models = ["ViT-e", "GPT 6.7B", "Llama 7B"];
    let systems = [
        SystemKind::MegatronHet,
        SystemKind::FlashFlex,
        SystemKind::Cephalo,
    ];
    let batches = [512usize, 1024];
    let mut headers = vec!["System".to_string()];
    for m in models {
        headers.push(format!("{m} @512"));
        headers.push(format!("{m} @1024"));
    }
    let mut t = Table::new(
        "Table 5 — throughput (samples/s), Cluster B (64 GPUs)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let registry = PlannerRegistry::with_defaults();
    let planners: Vec<_> = systems
        .iter()
        .map(|s| registry.get(s.name()).expect("registered"))
        .collect();
    let workloads: Vec<Workload> = models
        .iter()
        .map(|m| {
            Workload::prepare(Cluster::cluster_b(), m, 42).expect("profile")
        })
        .collect();
    let grids: Vec<Vec<SweepCell>> = workloads
        .iter()
        .map(|w| sweep(&w.ctx(0), &planners, &batches, None))
        .collect();

    for system in systems {
        let mut row = vec![system.name().to_string()];
        for cells in &grids {
            for &batch in &batches {
                row.push(outcome_cell(
                    &find_cell(cells, system, batch).result,
                ));
            }
        }
        t.add_row(row);
    }
    println!("{}", t.render());

    // Shape: Cephalo clearly ahead of the best baseline (§4.3: 2-10x).
    for (i, cells) in grids.iter().enumerate() {
        for &batch in &batches {
            let c = find_cell(cells, SystemKind::Cephalo, batch)
                .throughput()
                .unwrap_or_else(|| {
                    panic!("Cephalo OOM on {} @{batch}", models[i])
                });
            let best_baseline = [SystemKind::MegatronHet,
                                 SystemKind::FlashFlex]
                .iter()
                .filter_map(|s| find_cell(cells, *s, batch).throughput())
                .fold(0.0f64, f64::max);
            if best_baseline > 0.0 {
                let ratio = c / best_baseline;
                assert!(
                    ratio > 1.2,
                    "{}: Cephalo speedup only {ratio:.2}x @{batch}",
                    models[i]
                );
                println!(
                    "{} @{batch}: Cephalo {c:.2}, best baseline \
                     {best_baseline:.2} ({ratio:.1}x)",
                    models[i]
                );
            }
        }
    }
}
