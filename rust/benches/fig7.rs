//! Fig. 7: throughput vs batch size for FSDP, Cephalo-CB (compute
//! balancing only), Cephalo-MB (memory balancing only), and full
//! Cephalo — ViT-e, GPT 2.7B, Llama 3B on Cluster A. Every variant
//! comes out of the planner registry, and every feasible plan is
//! re-measured on the SHARED simulator (`Workload::simulate`), not its
//! planner's optimistic internal model.

use std::sync::Arc;

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::plan::{sweep, CephaloPlanner, Planner, PlannerRegistry};
use cephalo::sim::cephalo::evaluate_outcome;
use cephalo::sim::GaVariant;
use cephalo::util::tablefmt::Table;

fn main() {
    let batches = [32usize, 64, 96, 128, 160, 192, 224, 256];
    let variants = ["FSDP", "Cephalo-CB", "Cephalo-MB", "Cephalo"];
    let registry = PlannerRegistry::with_defaults();
    // FSDP-even is the ablation-scale FSDP plan; Cephalo runs with
    // simulate=false because evaluate_outcome below re-measures every
    // assignment on the shared simulator anyway — simulating inside
    // the planner too would do the work twice for identical numbers.
    let planners: Vec<Arc<dyn Planner>> = vec![
        registry.get("fsdp-even").expect("registered"),
        registry.get("cephalo-cb").expect("registered"),
        registry.get("cephalo-mb").expect("registered"),
        Arc::new(CephaloPlanner { simulate: false, ..Default::default() }),
    ];

    for model in ["ViT-e", "GPT 2.7B", "Llama 3B"] {
        let w = Workload::prepare(Cluster::cluster_a(), model, 42)
            .expect("profile");
        let mut headers = vec!["variant".to_string()];
        headers.extend(batches.iter().map(|b| format!("@{b}")));
        let mut t = Table::new(
            &format!("Fig. 7 — {model} on Cluster A (samples/s)"),
            &headers.iter().map(String::as_str).collect::<Vec<_>>(),
        );

        // The whole (variant x batch) grid solves in parallel; every
        // feasible outcome is then measured once on the one shared
        // simulator (evaluate_outcome re-simulates assignments and
        // passes assignment-less outcomes' own numbers through).
        let cells = sweep(&w.ctx(0), &planners, &batches, None);
        let mut rows: Vec<(String, Vec<Option<f64>>)> = Vec::new();
        for (v, name) in variants.iter().enumerate() {
            let mut row = vec![name.to_string()];
            let mut series = Vec::new();
            for (b, _) in batches.iter().enumerate() {
                let cell = &cells[v * batches.len() + b];
                let sim = cell.result.as_ref().ok().map(|o| {
                    evaluate_outcome(
                        &w.model,
                        &w.oracle,
                        &w.collective,
                        o,
                        GaVariant::LGA_CO_S_O,
                    )
                    .throughput
                });
                match sim {
                    Some(tput) => {
                        row.push(format!("{tput:.2}"));
                        series.push(Some(tput));
                    }
                    None => {
                        row.push("OOM".into());
                        series.push(None);
                    }
                }
            }
            t.add_row(row);
            rows.push((name.to_string(), series));
        }
        println!("{}", t.render());

        // Shape: CB OOMs beyond ~batch 100; MB never OOMs but is slow;
        // Cephalo never OOMs and dominates at 256.
        let get = |name: &str| {
            rows.iter().find(|(n, _)| n == name).unwrap().1.clone()
        };
        let cb = get("Cephalo-CB");
        let mb = get("Cephalo-MB");
        let full = get("Cephalo");
        assert!(cb.last().unwrap().is_none(), "{model}: CB should OOM @256");
        assert!(mb.iter().all(Option::is_some), "{model}: MB should fit");
        assert!(full.iter().all(Option::is_some),
                "{model}: Cephalo should fit");
        let f256 = full.last().unwrap().unwrap();
        let m256 = mb.last().unwrap().unwrap();
        assert!(f256 > 1.5 * m256,
                "{model}: Cephalo {f256:.2} should dominate MB {m256:.2}");
        println!("shape check [{model}]: CB OOMs, MB slow, Cephalo wins \
                  [ok]\n");
    }
}
