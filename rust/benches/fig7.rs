//! Fig. 7: throughput vs batch size for FSDP, Cephalo-CB (compute
//! balancing only), Cephalo-MB (memory balancing only), and full
//! Cephalo — ViT-e, GPT 2.7B, Llama 3B on Cluster A. Every variant is
//! measured on the shared simulator.

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::optimizer::ablations;
use cephalo::sim::GaVariant;
use cephalo::util::tablefmt::Table;

fn main() {
    let batches = [32usize, 64, 96, 128, 160, 192, 224, 256];
    for model in ["ViT-e", "GPT 2.7B", "Llama 3B"] {
        let w = Workload::prepare(Cluster::cluster_a(), model, 42)
            .expect("profile");
        let mut headers = vec!["variant".to_string()];
        headers.extend(batches.iter().map(|b| format!("@{b}")));
        let mut t = Table::new(
            &format!("Fig. 7 — {model} on Cluster A (samples/s)"),
            &headers.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        let mut rows: Vec<(String, Vec<Option<f64>>)> = Vec::new();
        for (name, f) in [
            ("FSDP", plan_fsdp as PlanFn),
            ("Cephalo-CB", plan_cb as PlanFn),
            ("Cephalo-MB", plan_mb as PlanFn),
            ("Cephalo", plan_full as PlanFn),
        ] {
            let mut row = vec![name.to_string()];
            let mut series = Vec::new();
            for &b in &batches {
                match f(&w, b) {
                    Some(asg) => {
                        let s = w.simulate(&asg, GaVariant::LGA_CO_S_O);
                        row.push(format!("{:.2}", s.throughput));
                        series.push(Some(s.throughput));
                    }
                    None => {
                        row.push("OOM".into());
                        series.push(None);
                    }
                }
            }
            t.add_row(row);
            rows.push((name.to_string(), series));
        }
        println!("{}", t.render());

        // Shape: CB OOMs beyond ~batch 100; MB never OOMs but is slow;
        // Cephalo never OOMs and dominates at 256.
        let get = |name: &str| {
            rows.iter().find(|(n, _)| n == name).unwrap().1.clone()
        };
        let cb = get("Cephalo-CB");
        let mb = get("Cephalo-MB");
        let full = get("Cephalo");
        assert!(cb.last().unwrap().is_none(), "{model}: CB should OOM @256");
        assert!(mb.iter().all(Option::is_some), "{model}: MB should fit");
        assert!(full.iter().all(Option::is_some),
                "{model}: Cephalo should fit");
        let f256 = full.last().unwrap().unwrap();
        let m256 = mb.last().unwrap().unwrap();
        assert!(f256 > 1.5 * m256,
                "{model}: Cephalo {f256:.2} should dominate MB {m256:.2}");
        println!("shape check [{model}]: CB OOMs, MB slow, Cephalo wins \
                  [ok]\n");
    }
}

type PlanFn = fn(&Workload, usize) -> Option<cephalo::optimizer::Assignment>;

fn plan_fsdp(w: &Workload, b: usize) -> Option<cephalo::optimizer::Assignment> {
    ablations::fsdp_even(&w.profile, b).ok()
}

fn plan_cb(w: &Workload, b: usize) -> Option<cephalo::optimizer::Assignment> {
    ablations::compute_balanced_only(&w.profile, b).ok()
}

fn plan_mb(w: &Workload, b: usize) -> Option<cephalo::optimizer::Assignment> {
    ablations::memory_balanced_only(&w.profile, b).ok()
}

fn plan_full(w: &Workload, b: usize)
    -> Option<cephalo::optimizer::Assignment> {
    w.optimize(b).ok().map(|(a, _)| a)
}
