//! Elastic-session bench: live steps/sec across a churn trace on the
//! native backend, the PlanCache payoff — cache-hit re-plans vs cold
//! DP solves — and the span-tracer overhead (traced vs untraced
//! session throughput must stay inside the perf-gate noise band).

use std::sync::Arc;

use cephalo::benchkit::{self, Bencher, RATE_NOISE_BAND};
use cephalo::cluster::Cluster;
use cephalo::coordinator::session::{Session, SessionConfig};
use cephalo::coordinator::{elastic, Workload};
use cephalo::plan::{CephaloPlanner, PlanCache, Planner};
use cephalo::util::json::Json;
use cephalo::util::tablefmt::Table;

/// One live churn session on the in-process native backend; returns
/// (wall steps/sec, events run). Tracing state is whatever the caller
/// set — that is the variable under test.
fn run_session(planner: &Arc<dyn Planner>, events: usize) -> (f64, usize) {
    let cfg = SessionConfig {
        batch: 64,
        steps_per_event: 3,
        seed: 42,
        ..Default::default()
    };
    let mut session =
        Session::new(Cluster::cluster_a(), Arc::clone(planner), cfg)
            .expect("session");
    let t0 = std::time::Instant::now();
    let reports = session.run(events).expect("live session");
    let wall = t0.elapsed().as_secs_f64();
    let steps = session.trainer().history.len();
    (steps as f64 / wall, reports.len())
}

fn main() {
    let (quick, json) = benchkit::bench_args();
    // The session is cheap enough to run full-length even in --quick;
    // shrinking the event count would also shrink the recurring
    // memberships the cache-hit assertion depends on.
    let events = 6;
    let mut b = Bencher::new(1, 7);

    // ---- Re-plan latency: cold solve vs recurring-membership hit ----
    let planner = CephaloPlanner::default();
    let full = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
        .expect("workload");
    let (asg, _) = full.optimize(64).expect("plan");
    let survivors: Vec<Option<usize>> = (0..8).map(Some).collect();

    let cold = b
        .bench("replan: cold DP solve", || {
            // Fresh cache every iteration -> every re-plan solves.
            let cache = PlanCache::new();
            elastic::replan(&asg, &full.profile, &full.ctx(64),
                            &survivors, &planner, Some(&cache))
                .expect("replan")
                .solve_seconds
        })
        .mean_s;

    let warm_cache = PlanCache::new();
    elastic::replan(&asg, &full.profile, &full.ctx(64), &survivors,
                    &planner, Some(&warm_cache))
        .expect("warm");
    let hit = b
        .bench("replan: recurring membership (cache hit)", || {
            let re = elastic::replan(&asg, &full.profile, &full.ctx(64),
                                     &survivors, &planner,
                                     Some(&warm_cache))
                .expect("replan");
            assert!(re.from_cache);
            re.moved_elems
        })
        .mean_s;

    // ---- Live session: steps/sec across a churn trace ----
    let planner: Arc<dyn Planner> = Arc::new(CephaloPlanner::default());
    let cfg = SessionConfig {
        batch: 64,
        steps_per_event: 3,
        seed: 42,
        ..Default::default()
    };
    let mut session =
        Session::new(Cluster::cluster_a(), Arc::clone(&planner), cfg)
            .expect("session");
    let t0 = std::time::Instant::now();
    let reports = session.run(events).expect("live session");
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "Live elastic session across the churn trace (native backend)",
        &["event", "gpus", "plan", "state moved (GB)", "sim steps/s",
          "wall steps/s"],
    );
    for r in &reports {
        t.add_row(vec![
            r.event.to_string(),
            r.gpus.to_string(),
            String::from(if r.from_cache { "hit" } else { "solve" }),
            format!("{:.2}", r.migration_bytes / 1e9),
            format!("{:.2}", r.steps_per_sec),
            format!("{:.2}", r.measured_steps_per_sec),
        ]);
    }
    println!("{}", t.render());
    let steps = session.trainer().history.len();
    let untraced_sps = steps as f64 / wall;
    println!(
        "{steps} live steps over {} events in {wall:.2}s wall \
         ({untraced_sps:.1} steps/s executed); plan cache {} hits / {} \
         misses",
        reports.len(),
        session.cache().hits(),
        session.cache().misses()
    );
    assert!(
        session.cache().hits() >= 1,
        "recurring memberships should hit the cache"
    );
    drop(session);

    // ---- Tracer overhead: the same session with spans recording ----
    cephalo::telemetry::reset();
    cephalo::telemetry::enable();
    let (traced_sps, _) = run_session(&planner, events);
    cephalo::telemetry::drain();
    let trace_events = cephalo::telemetry::take_events().len();
    cephalo::telemetry::reset();
    println!(
        "tracer overhead: {untraced_sps:.1} steps/s untraced vs \
         {traced_sps:.1} traced ({trace_events} events recorded)"
    );
    println!("{}", b.render_markdown("Elastic re-plan latency"));

    assert!(
        hit < cold,
        "cache hit ({hit:.6}s) should beat a cold solve ({cold:.6}s)"
    );
    assert!(
        traced_sps >= untraced_sps * (1.0 - RATE_NOISE_BAND),
        "span tracing dragged the session out of the noise band: \
         {traced_sps:.2} traced vs {untraced_sps:.2} untraced steps/s"
    );
    println!(
        "shape check: hit {hit:.2e}s < cold solve {cold:.2e}s; traced \
         within {RATE_NOISE_BAND} band  [ok]"
    );

    if let Some(path) = json {
        use std::collections::BTreeMap;
        let mut row = BTreeMap::new();
        row.insert("case".to_string(),
                   Json::Str("live_churn_session".into()));
        row.insert("untraced_steps_per_sec".to_string(),
                   Json::Num(untraced_sps));
        row.insert("traced_steps_per_sec".to_string(),
                   Json::Num(traced_sps));
        row.insert("replan_cold_per_sec".to_string(),
                   Json::Num(1.0 / cold.max(1e-12)));
        row.insert("replan_cache_hit_per_sec".to_string(),
                   Json::Num(1.0 / hit.max(1e-12)));
        benchkit::write_json_rows(&path, "elastic_session", quick,
                                  vec![Json::Obj(row)]);
    }
}
