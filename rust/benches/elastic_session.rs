//! Elastic-session bench: live steps/sec across a churn trace on the
//! native backend, and the PlanCache payoff — cache-hit re-plans vs
//! cold DP solves — measured through `benchkit`.

use std::sync::Arc;

use cephalo::benchkit::Bencher;
use cephalo::cluster::Cluster;
use cephalo::coordinator::session::{Session, SessionConfig};
use cephalo::coordinator::{elastic, Workload};
use cephalo::plan::{CephaloPlanner, PlanCache, Planner};
use cephalo::util::tablefmt::Table;

fn main() {
    let mut b = Bencher::new(1, 7);

    // ---- Re-plan latency: cold solve vs recurring-membership hit ----
    let planner = CephaloPlanner::default();
    let full = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
        .expect("workload");
    let (asg, _) = full.optimize(64).expect("plan");
    let survivors: Vec<Option<usize>> = (0..8).map(Some).collect();

    let cold = b
        .bench("replan: cold DP solve", || {
            // Fresh cache every iteration -> every re-plan solves.
            let cache = PlanCache::new();
            elastic::replan(&asg, &full.profile, &full.ctx(64),
                            &survivors, &planner, Some(&cache))
                .expect("replan")
                .solve_seconds
        })
        .mean_s;

    let warm_cache = PlanCache::new();
    elastic::replan(&asg, &full.profile, &full.ctx(64), &survivors,
                    &planner, Some(&warm_cache))
        .expect("warm");
    let hit = b
        .bench("replan: recurring membership (cache hit)", || {
            let re = elastic::replan(&asg, &full.profile, &full.ctx(64),
                                     &survivors, &planner,
                                     Some(&warm_cache))
                .expect("replan");
            assert!(re.from_cache);
            re.moved_elems
        })
        .mean_s;

    // ---- Live session: steps/sec across a 6-event churn trace ----
    let planner: Arc<dyn Planner> = Arc::new(CephaloPlanner::default());
    let cfg = SessionConfig {
        batch: 64,
        steps_per_event: 3,
        seed: 42,
        ..Default::default()
    };
    let mut session =
        Session::new(Cluster::cluster_a(), Arc::clone(&planner), cfg)
            .expect("session");
    let t0 = std::time::Instant::now();
    let reports = session.run(6).expect("live session");
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "Live elastic session across the churn trace (native backend)",
        &["event", "gpus", "plan", "state moved (GB)", "sim steps/s",
          "wall steps/s"],
    );
    for r in &reports {
        t.add_row(vec![
            r.event.to_string(),
            r.gpus.to_string(),
            String::from(if r.from_cache { "hit" } else { "solve" }),
            format!("{:.2}", r.migration_bytes / 1e9),
            format!("{:.2}", r.steps_per_sec),
            format!("{:.2}", r.measured_steps_per_sec),
        ]);
    }
    println!("{}", t.render());
    let steps = session.trainer().history.len();
    println!(
        "{steps} live steps over {} events in {wall:.2}s wall \
         ({:.1} steps/s executed); plan cache {} hits / {} misses",
        reports.len(),
        steps as f64 / wall,
        session.cache().hits(),
        session.cache().misses()
    );
    println!("{}", b.render_markdown("Elastic re-plan latency"));

    assert!(
        hit < cold,
        "cache hit ({hit:.6}s) should beat a cold solve ({cold:.6}s)"
    );
    assert!(
        session.cache().hits() >= 1,
        "recurring memberships should hit the cache"
    );
    println!("shape check: hit {hit:.2e}s < cold solve {cold:.2e}s  [ok]");
}
