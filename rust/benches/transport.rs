//! Transport bench: segmented-ring collective throughput (bytes/sec)
//! over the channel fabric vs TCP loopback, across shard sizes — the
//! cost of making the message plane real.
//!
//! Wire traffic per collective: every one of the N segments travels
//! N−1 hops, so a full AllGather or ReduceScatter moves
//! `(N−1) × len × 4` bytes.

use std::collections::BTreeMap;
use std::time::Instant;

use cephalo::sharding::ShardLayout;
use cephalo::transport::{collectives as wire, LocalFabric, Transport};
use cephalo::util::json::Json;
use cephalo::util::tablefmt::Table;

const WORLD: usize = 4;

fn local_fabric() -> Vec<Box<dyn Transport>> {
    LocalFabric::new(WORLD)
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

/// Mean seconds per collective round (all ranks in lockstep).
fn time_round(
    eps: &mut [Box<dyn Transport>],
    layout: &ShardLayout,
    iters: usize,
    reduce: bool,
) -> f64 {
    let shards: Vec<Vec<f32>> = (0..WORLD)
        .map(|r| vec![1.0f32; layout.size(r)])
        .collect();
    let fulls: Vec<Vec<f32>> =
        (0..WORLD).map(|_| vec![1.0f32; layout.len()]).collect();
    let t0 = Instant::now();
    for _ in 0..iters {
        std::thread::scope(|s| {
            for (r, ep) in eps.iter_mut().enumerate() {
                let shard = &shards[r];
                let full = &fulls[r];
                s.spawn(move || {
                    if reduce {
                        wire::ring_reduce_scatter(ep.as_mut(), full, layout)
                            .unwrap();
                    } else {
                        wire::ring_allgather(ep.as_mut(), shard, layout)
                            .unwrap();
                    }
                });
            }
        });
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn gbps(bytes: f64, secs: f64) -> String {
    format!("{:.3}", bytes / secs / 1e9)
}

fn main() {
    let (quick, json_path) = cephalo::benchkit::bench_args();
    let mut local = local_fabric();
    let mut tcp = cephalo::transport::tcp::thread_fabric(WORLD)
        .expect("loopback fabric");

    let mut t = Table::new(
        &format!(
            "Ring collective throughput over {WORLD} ranks \
             (wire GB/s, (N-1) x len x 4 bytes per round)"
        ),
        &["elems", "AG local", "AG tcp", "RS local", "RS tcp"],
    );
    // 2^17 elems puts each ring segment at 128 KiB on the wire — past
    // the dup-cache bound, so TCP rows take the vectored (writev)
    // bulk-frame path even in quick mode.
    let shifts: &[u32] = if quick { &[10, 17] } else { &[10, 14, 17] };
    let mut json_rows: Vec<Json> = Vec::new();
    for &shift in shifts {
        let len = 1usize << shift;
        let layout = ShardLayout::even(len, WORLD);
        let iters = if quick {
            3
        } else {
            ((1usize << 19) / len).clamp(3, 64)
        };
        let bytes = ((WORLD - 1) * len * 4) as f64;
        let ag_l = time_round(&mut local, &layout, iters, false);
        let ag_t = time_round(&mut tcp, &layout, iters, false);
        let rs_l = time_round(&mut local, &layout, iters, true);
        let rs_t = time_round(&mut tcp, &layout, iters, true);
        t.add_row(vec![
            len.to_string(),
            gbps(bytes, ag_l),
            gbps(bytes, ag_t),
            gbps(bytes, rs_l),
            gbps(bytes, rs_t),
        ]);
        let mut row = BTreeMap::new();
        row.insert("elems".into(), Json::Num(len as f64));
        row.insert("bytes_per_round".into(), Json::Num(bytes));
        row.insert("ag_local_gbps".into(), Json::Num(bytes / ag_l / 1e9));
        row.insert("ag_tcp_gbps".into(), Json::Num(bytes / ag_t / 1e9));
        row.insert("rs_local_gbps".into(), Json::Num(bytes / rs_l / 1e9));
        row.insert("rs_tcp_gbps".into(), Json::Num(bytes / rs_t / 1e9));
        json_rows.push(Json::Obj(row));
    }
    println!("{}", t.render());
    println!(
        "shape check: both fabrics completed every round over uneven \
         thread scheduling  [ok]"
    );
    if let Some(path) = json_path {
        cephalo::benchkit::write_json_rows(
            &path, "transport", quick, json_rows,
        );
    }
}
