//! Transport bench: segmented-ring collective throughput (bytes/sec)
//! over the channel fabric, TCP loopback, the /dev/shm ring-buffer
//! fabric and the locality-routed hybrid fabric, across shard sizes —
//! the cost of making the message plane real, and the payoff of the
//! same-host fast path.
//!
//! Wire traffic per collective: every one of the N segments travels
//! N−1 hops, so a full AllGather or ReduceScatter moves
//! `(N−1) × len × 4` bytes.
//!
//! The 2^17-elem shm rows are the tentpole's perf claim (ISSUE 8):
//! shm must sustain at least 2x the loopback-TCP wire rate, asserted
//! here and pinned across commits by `bench-gate`.

use std::collections::BTreeMap;
use std::time::Instant;

use cephalo::sharding::ShardLayout;
use cephalo::transport::shm::fresh_dir;
use cephalo::transport::{
    collectives as wire, HostTopology, HybridTransport, LocalFabric,
    ShmFabric, Transport,
};
use cephalo::util::json::Json;
use cephalo::util::tablefmt::Table;

const WORLD: usize = 4;

fn local_fabric() -> Vec<Box<dyn Transport>> {
    LocalFabric::new(WORLD)
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

fn shm_fabric() -> Vec<Box<dyn Transport>> {
    ShmFabric::new(WORLD)
        .expect("shm fabric")
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

/// Hybrid endpoints over hosts `[0, 0, 1, 1]`: half the lanes ride
/// shm, the cross-host half ride the channel fabric.
fn hybrid_fabric() -> Vec<Box<dyn Transport>> {
    let topo = HostTopology::new(vec![0, 0, 1, 1]);
    let dir = fresh_dir();
    LocalFabric::new(WORLD)
        .into_iter()
        .map(|slow| {
            Box::new(
                HybridTransport::wrap(
                    Box::new(slow),
                    &dir,
                    topo.clone(),
                )
                .expect("hybrid fabric"),
            ) as Box<dyn Transport>
        })
        .collect()
}

/// Mean seconds per collective round (all ranks in lockstep).
fn time_round(
    eps: &mut [Box<dyn Transport>],
    layout: &ShardLayout,
    iters: usize,
    reduce: bool,
) -> f64 {
    let shards: Vec<Vec<f32>> = (0..WORLD)
        .map(|r| vec![1.0f32; layout.size(r)])
        .collect();
    let fulls: Vec<Vec<f32>> =
        (0..WORLD).map(|_| vec![1.0f32; layout.len()]).collect();
    let t0 = Instant::now();
    for _ in 0..iters {
        std::thread::scope(|s| {
            for (r, ep) in eps.iter_mut().enumerate() {
                let shard = &shards[r];
                let full = &fulls[r];
                s.spawn(move || {
                    if reduce {
                        wire::ring_reduce_scatter(ep.as_mut(), full, layout)
                            .unwrap();
                    } else {
                        wire::ring_allgather(ep.as_mut(), shard, layout)
                            .unwrap();
                    }
                });
            }
        });
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn gbps(bytes: f64, secs: f64) -> String {
    format!("{:.3}", bytes / secs / 1e9)
}

fn main() {
    let (quick, json_path) = cephalo::benchkit::bench_args();
    let mut local = local_fabric();
    let mut tcp = cephalo::transport::tcp::thread_fabric(WORLD)
        .expect("loopback fabric");
    let mut shm = shm_fabric();
    let mut hybrid = hybrid_fabric();

    let mut t = Table::new(
        &format!(
            "Ring collective throughput over {WORLD} ranks \
             (wire GB/s, (N-1) x len x 4 bytes per round)"
        ),
        &["elems", "AG local", "AG tcp", "AG shm", "AG hybrid",
          "RS local", "RS tcp", "RS shm", "RS hybrid"],
    );
    // 2^17 elems puts each ring segment at 128 KiB on the wire — past
    // the dup-cache bound, so TCP rows take the vectored (writev)
    // bulk-frame path even in quick mode.
    let shifts: &[u32] = if quick { &[10, 17] } else { &[10, 14, 17] };
    let mut json_rows: Vec<Json> = Vec::new();
    for &shift in shifts {
        let len = 1usize << shift;
        let layout = ShardLayout::even(len, WORLD);
        // Quick rows feed the cross-run perf gate, whose rate noise
        // band is 0.25: 8 iterations keeps single-scheduler-hiccup
        // jitter well inside it (3 did not).
        let iters = if quick {
            8
        } else {
            ((1usize << 19) / len).clamp(3, 64)
        };
        let bytes = ((WORLD - 1) * len * 4) as f64;
        let ag_l = time_round(&mut local, &layout, iters, false);
        let ag_t = time_round(&mut tcp, &layout, iters, false);
        let ag_s = time_round(&mut shm, &layout, iters, false);
        let ag_h = time_round(&mut hybrid, &layout, iters, false);
        let rs_l = time_round(&mut local, &layout, iters, true);
        let rs_t = time_round(&mut tcp, &layout, iters, true);
        let rs_s = time_round(&mut shm, &layout, iters, true);
        let rs_h = time_round(&mut hybrid, &layout, iters, true);
        t.add_row(vec![
            len.to_string(),
            gbps(bytes, ag_l),
            gbps(bytes, ag_t),
            gbps(bytes, ag_s),
            gbps(bytes, ag_h),
            gbps(bytes, rs_l),
            gbps(bytes, rs_t),
            gbps(bytes, rs_s),
            gbps(bytes, rs_h),
        ]);
        if shift == 17 {
            // The tentpole claim: same-host lanes must beat loopback
            // sockets by at least 2x where the bandwidth term
            // dominates. A miss is a perf regression, not noise.
            assert!(
                ag_s * 2.0 <= ag_t && rs_s * 2.0 <= rs_t,
                "shm rings must be >= 2x loopback TCP at 2^17 elems: \
                 AG {} vs {} GB/s, RS {} vs {} GB/s",
                gbps(bytes, ag_s),
                gbps(bytes, ag_t),
                gbps(bytes, rs_s),
                gbps(bytes, rs_t),
            );
            println!(
                "shm >= 2x loopback TCP at 2^17 elems \
                 (AG {:.1}x, RS {:.1}x)  [ok]",
                ag_t / ag_s,
                rs_t / rs_s
            );
        }
        let mut row = BTreeMap::new();
        row.insert("elems".into(), Json::Num(len as f64));
        row.insert("bytes_per_round".into(), Json::Num(bytes));
        row.insert("ag_local_gbps".into(), Json::Num(bytes / ag_l / 1e9));
        row.insert("ag_tcp_gbps".into(), Json::Num(bytes / ag_t / 1e9));
        row.insert("ag_shm_gbps".into(), Json::Num(bytes / ag_s / 1e9));
        row.insert(
            "ag_hybrid_gbps".into(),
            Json::Num(bytes / ag_h / 1e9),
        );
        row.insert("rs_local_gbps".into(), Json::Num(bytes / rs_l / 1e9));
        row.insert("rs_tcp_gbps".into(), Json::Num(bytes / rs_t / 1e9));
        row.insert("rs_shm_gbps".into(), Json::Num(bytes / rs_s / 1e9));
        row.insert(
            "rs_hybrid_gbps".into(),
            Json::Num(bytes / rs_h / 1e9),
        );
        json_rows.push(Json::Obj(row));
    }
    println!("{}", t.render());
    println!(
        "shape check: all four fabrics completed every round over \
         uneven thread scheduling  [ok]"
    );
    if let Some(path) = json_path {
        cephalo::benchkit::write_json_rows(
            &path, "transport", quick, json_rows,
        );
    }
}
