//! Fig. 1: hourly AWS GPU availability over a 12-hour window (synthetic
//! trace generator; see DESIGN.md §Substitutions). High-end GPUs are
//! nearly always unavailable; mid-tier limited.

use cephalo::cluster::aws_trace::{default_profiles, generate,
                                  mean_available,
                                  unavailability_fraction};
use cephalo::util::tablefmt::Table;

fn main() {
    let profiles = default_profiles();
    let trace = generate(42, 12, &profiles);

    let mut headers = vec!["hour".to_string()];
    headers.extend(profiles.iter().map(|p| p.gpu.clone()));
    let mut t = Table::new(
        "Fig. 1 — AWS GPU availability (instances obtainable per hour)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for h in &trace {
        let mut row = vec![h.hour.to_string()];
        row.extend(h.available.iter().map(|(_, c)| c.to_string()));
        t.add_row(row);
    }
    println!("{}", t.render());

    let mut s = Table::new(
        "Fig. 1 — summary over a 240h extended trace",
        &["gpu", "hours unavailable (%)", "mean instances"],
    );
    let long = generate(42, 240, &profiles);
    for p in &profiles {
        s.add_row(vec![
            p.gpu.clone(),
            format!("{:.0}", unavailability_fraction(&long, &p.gpu) * 100.0),
            format!("{:.1}", mean_available(&long, &p.gpu)),
        ]);
    }
    println!("{}", s.render());

    assert!(unavailability_fraction(&long, "H100") > 0.7);
    assert!(unavailability_fraction(&long, "A100") > 0.6);
    assert!(unavailability_fraction(&long, "T4") < 0.5);
    println!("shape check: high-end scarce, mid-tier limited  [ok]");
}
