//! Table 4: training throughput (samples/s) on the 8-GPU Cluster A —
//! 8 models x batch {128, 256} x {Megatron-Het, FlashFlex, Cephalo},
//! every cell produced by ONE parallel `plan::sweep` per workload.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{find_cell, outcome_cell, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::plan::{sweep, PlannerRegistry, SweepCell};
use cephalo::util::tablefmt::Table;

fn main() {
    let models = [
        "ViT-G", "ViT-e", "BERT-Large", "BERT-XLarge", "GPT 1.3B",
        "GPT 2.7B", "Tiny Llama", "Llama 3B",
    ];
    let systems = [
        SystemKind::MegatronHet,
        SystemKind::FlashFlex,
        SystemKind::Cephalo,
    ];
    let batches = [128usize, 256];
    let mut headers = vec!["System".to_string()];
    for m in models {
        headers.push(format!("{m} @128"));
        headers.push(format!("{m} @256"));
    }
    let mut t = Table::new(
        "Table 4 — throughput (samples/s), Cluster A",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let registry = PlannerRegistry::with_defaults();
    let planners: Vec<_> = systems
        .iter()
        .map(|s| registry.get(s.name()).expect("registered"))
        .collect();

    let workloads: Vec<Workload> = models
        .iter()
        .map(|m| {
            Workload::prepare(Cluster::cluster_a(), m, 42).expect("profile")
        })
        .collect();
    // One parallel (system x batch) sweep per workload.
    let grids: Vec<Vec<SweepCell>> = workloads
        .iter()
        .map(|w| sweep(&w.ctx(0), &planners, &batches, None))
        .collect();

    for system in systems {
        let mut row = vec![system.name().to_string()];
        for cells in &grids {
            for &batch in &batches {
                row.push(outcome_cell(
                    &find_cell(cells, system, batch).result,
                ));
            }
        }
        t.add_row(row);
    }
    println!("{}", t.render());

    // Shape assertions (the paper's qualitative results) straight off
    // the sweep cells — no re-solving.
    for (i, cells) in grids.iter().enumerate() {
        for &batch in &batches {
            let c = find_cell(cells, SystemKind::Cephalo, batch)
                .throughput()
                .unwrap_or_else(|| {
                    panic!("Cephalo OOM on {} @{batch}", models[i])
                });
            for other in [SystemKind::MegatronHet, SystemKind::FlashFlex] {
                if let Some(o) =
                    find_cell(cells, other, batch).throughput()
                {
                    assert!(
                        c > o,
                        "{} beat Cephalo on {} @{batch}: {o:.2} vs {c:.2}",
                        other.name(),
                        models[i]
                    );
                }
            }
        }
    }
    println!("shape check: Cephalo wins every cell without OOM  [ok]");
}
