//! Table 4: training throughput (samples/s) on the 8-GPU Cluster A —
//! 8 models x batch {128, 256} x {Megatron-Het, FlashFlex, Cephalo}.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{cell, throughput, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::util::tablefmt::Table;

fn main() {
    let models = [
        "ViT-G", "ViT-e", "BERT-Large", "BERT-XLarge", "GPT 1.3B",
        "GPT 2.7B", "Tiny Llama", "Llama 3B",
    ];
    let systems = [
        SystemKind::MegatronHet,
        SystemKind::FlashFlex,
        SystemKind::Cephalo,
    ];
    let mut headers = vec!["System".to_string()];
    for m in models {
        headers.push(format!("{m} @128"));
        headers.push(format!("{m} @256"));
    }
    let mut t = Table::new(
        "Table 4 — throughput (samples/s), Cluster A",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let workloads: Vec<Workload> = models
        .iter()
        .map(|m| {
            Workload::prepare(Cluster::cluster_a(), m, 42).expect("profile")
        })
        .collect();

    for system in systems {
        let mut row = vec![system.name().to_string()];
        for w in &workloads {
            row.push(cell(w, 128, system));
            row.push(cell(w, 256, system));
        }
        t.add_row(row);
    }
    println!("{}", t.render());

    // Shape assertions (the paper's qualitative results).
    for (i, w) in workloads.iter().enumerate() {
        for batch in [128usize, 256] {
            let c = throughput(w, batch, SystemKind::Cephalo);
            assert!(c.is_ok(), "Cephalo OOM on {} @{batch}", models[i]);
            let c = c.unwrap();
            for other in [SystemKind::MegatronHet, SystemKind::FlashFlex] {
                if let Ok(o) = throughput(w, batch, other) {
                    assert!(
                        c > o,
                        "{} beat Cephalo on {} @{batch}: {o:.2} vs {c:.2}",
                        other.name(),
                        models[i]
                    );
                }
            }
        }
    }
    println!("shape check: Cephalo wins every cell without OOM  [ok]");
}
