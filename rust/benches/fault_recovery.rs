//! Fault-recovery bench: the latency of surviving a worker crash —
//! heartbeat detection, cached re-plan, mirror-sourced wire migration —
//! measured per recovery on a live chaos session, over the channel
//! fabric and TCP loopback, leader-resident and fully-sharded.
//!
//! Every run replays the SAME seeded fault schedule, so rows are
//! comparable across fabrics and across commits. The byte/element
//! columns (migration bytes, mirror-sourced state elements) are
//! deterministic accounting, not timings — the perf gate pins them
//! exactly; a drift means the recovery path moved different data.
//!
//! The rejoin section measures the OTHER fate of a suspected rank:
//! healed inside the rejoin window. A fingerprint hit resumes in
//! place (zero elements moved); a chaos-tainted digest forces the
//! re-stream path (the rank's state re-sourced from the mirror with
//! no membership change). The `path` column keys the two.

use std::collections::BTreeMap;
use std::sync::Arc;

use cephalo::cluster::catalog::find;
use cephalo::cluster::{Cluster, Node};
use cephalo::coordinator::session::{
    RecoveryReport, RejoinReport, Session, SessionConfig,
};
use cephalo::plan::CephaloPlanner;
use cephalo::transport::FabricSpec;
use cephalo::util::json::Json;
use cephalo::util::tablefmt::Table;

/// Five heterogeneous GPUs on one node: room for three crashes
/// (ranks 4, 3, 2) above a 2-rank quorum.
fn cluster5() -> Cluster {
    Cluster {
        name: "bench5".into(),
        nodes: vec![Node {
            name: "n0".into(),
            gpus: vec![
                find("T4").unwrap(),
                find("V100").unwrap(),
                find("P40").unwrap(),
                find("P100").unwrap(),
                find("L4").unwrap(),
            ],
            intra_bw_gbps: 64.0,
        }],
        inter_bw_gbps: 50.0,
    }
}

/// One chaos session to completion; returns its recovery reports.
fn run(
    fabric: FabricSpec,
    shard_params: bool,
    chaos: &str,
    events: usize,
) -> Vec<RecoveryReport> {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: 8,
        steps_per_event: 2,
        seed: 13,
        min_gpus: 1,
        fabric: Some(fabric),
        shard_params,
        chaos: Some(chaos.to_string()),
        ..Default::default()
    };
    let mut session =
        Session::new(cluster5(), Arc::new(CephaloPlanner::default()), cfg)
            .expect("chaos session starts");
    for hour in 0..events {
        session.step_event(hour, 5).expect("event survives its faults");
    }
    session.recoveries.clone()
}

/// One rejoin-enabled chaos session (fully-sharded, 3 ranks) to
/// completion; returns its rejoin reports. The schedule drops one PING
/// echo, so exactly one suspicion is raised and healed per run.
fn run_rejoin(fabric: FabricSpec, chaos: &str) -> Vec<RejoinReport> {
    let cfg = SessionConfig {
        model: "BERT-Large".into(),
        batch: 8,
        steps_per_event: 2,
        seed: 13,
        min_gpus: 1,
        fabric: Some(fabric),
        shard_params: true,
        chaos: Some(chaos.to_string()),
        rejoin_window_ms: 5000,
        ping_timeout_ms: 200,
        ..Default::default()
    };
    let mut session = Session::new(
        cephalo::testkit::tiny_cluster3(),
        Arc::new(CephaloPlanner::default()),
        cfg,
    )
    .expect("rejoin session starts");
    for hour in 0..2 {
        session.step_event(hour, 3).expect("event survives its faults");
    }
    assert!(
        session.recoveries.is_empty(),
        "a healed partition must not migrate"
    );
    session.rejoins.clone()
}

fn main() {
    let (quick, json_path) = cephalo::benchkit::bench_args();
    // Quick mode schedules one crash over 3 events; the full schedule
    // kills three ranks (the last by step 9) over 7 events.
    let (chaos, events) = if quick {
        ("seed=3,crash=1,first=1,stride=2,delay=0,dup=0", 3)
    } else {
        ("seed=3,crash=3,first=1,stride=2,delay=0,dup=0", 7)
    };

    let mut t = Table::new(
        "Crash recovery latency (per detected failure)",
        &["fabric", "residency", "step", "dead", "gpus", "detect (ms)",
          "replan (ms)", "migrate (ms)", "migr bytes", "moved elems"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let cases = [
        (FabricSpec::Local, false, "local", "leader"),
        (FabricSpec::Local, true, "local", "sharded"),
        (FabricSpec::TcpThreads, false, "tcp", "leader"),
        (FabricSpec::TcpThreads, true, "tcp", "sharded"),
    ];
    for (fabric, shard, fabric_label, mode) in cases {
        let recoveries = run(fabric, shard, chaos, events);
        assert!(
            !recoveries.is_empty(),
            "the schedule must produce at least one recovery"
        );
        for r in &recoveries {
            t.add_row(vec![
                fabric_label.to_string(),
                mode.to_string(),
                r.step.to_string(),
                format!("{:?}", r.ranks),
                r.gpus.to_string(),
                format!("{:.2}", r.detect_ms),
                format!("{:.2}", r.replan_ms),
                format!("{:.2}", r.migrate_ms),
                format!("{:.0}", r.migration_bytes),
                r.moved_state_elems.to_string(),
            ]);
            let mut row = BTreeMap::new();
            row.insert("fabric".into(), Json::Str(fabric_label.into()));
            row.insert("residency".into(), Json::Str(mode.into()));
            // As a string, `step` joins the row's identity prefix, so
            // each recovery of a (fabric, residency) case keeps its
            // own Exact metrics instead of colliding on flatten.
            row.insert("step".into(), Json::Str(r.step.to_string()));
            row.insert(
                "dead_ranks".into(),
                Json::Arr(
                    r.ranks.iter().map(|&x| Json::Num(x as f64)).collect(),
                ),
            );
            row.insert("gpus_after".into(), Json::Num(r.gpus as f64));
            row.insert("detect_ms".into(), Json::Num(r.detect_ms));
            row.insert("replan_ms".into(), Json::Num(r.replan_ms));
            row.insert("migrate_ms".into(), Json::Num(r.migrate_ms));
            row.insert(
                "migration_bytes".into(),
                Json::Num(r.migration_bytes),
            );
            row.insert(
                "moved_state_elems".into(),
                Json::Num(r.moved_state_elems as f64),
            );
            json_rows.push(Json::Obj(row));
        }
    }
    println!("{}", t.render());
    println!(
        "every recovery re-joined the reference trajectory bitwise \
         (asserted in tests/dist_session.rs)  [ok]"
    );

    // Rejoin-after-partition: the drop fires at the second liveness
    // poll; `taint` additionally corrupts the reported digest, forcing
    // the re-stream path on the second case.
    let mut rt = Table::new(
        "Rejoin latency (per healed partition)",
        &["fabric", "path", "step", "rank", "probes", "migrate (ms)",
          "moved elems"],
    );
    let drop_chaos =
        "seed=11,crash=0,delay=0,dup=0,drop_ping=2,drop_first=2";
    let taint_chaos =
        "seed=11,crash=0,delay=0,dup=0,drop_ping=2,drop_first=2,taint=2";
    let rejoin_cases = [
        (FabricSpec::TcpThreads, "tcp", drop_chaos),
        (FabricSpec::TcpThreads, "tcp", taint_chaos),
    ];
    for (fabric, fabric_label, chaos) in rejoin_cases {
        let rejoins = run_rejoin(fabric, chaos);
        assert!(
            !rejoins.is_empty(),
            "the schedule must heal at least one partition"
        );
        for r in &rejoins {
            let path = if r.hit { "in-place" } else { "re-stream" };
            rt.add_row(vec![
                fabric_label.to_string(),
                path.to_string(),
                r.step.to_string(),
                r.rank.to_string(),
                r.attempts.to_string(),
                format!("{:.2}", r.migrate_ms),
                r.moved_state_elems.to_string(),
            ]);
            let mut row = BTreeMap::new();
            row.insert("fabric".into(), Json::Str(fabric_label.into()));
            // `path` keys the two rejoin fates into distinct metric
            // prefixes (an in-place heal pins moved elems at 0; a
            // re-stream pins the mirror-sourced volume).
            row.insert("path".into(), Json::Str(path.into()));
            row.insert("step".into(), Json::Str(r.step.to_string()));
            row.insert("rank".into(), Json::Num(r.rank as f64));
            row.insert("probes".into(), Json::Num(r.attempts as f64));
            row.insert("migrate_ms".into(), Json::Num(r.migrate_ms));
            row.insert(
                "moved_state_elems".into(),
                Json::Num(r.moved_state_elems as f64),
            );
            json_rows.push(Json::Obj(row));
        }
    }
    println!("{}", rt.render());
    println!(
        "every rejoin stayed on the reference trajectory bitwise \
         (asserted in tests/dist_session.rs)  [ok]"
    );
    if let Some(path) = json_path {
        cephalo::benchkit::write_json_rows(
            &path, "fault_recovery", quick, json_rows,
        );
    }
}
