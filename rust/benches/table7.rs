//! Table 7: profiling + optimization runtime breakdown for the largest
//! workload (GPT 6.7B, 64 GPUs, batch 512). The paper reports 987 s on
//! their testbed; here the profiling subtasks sample the synthetic
//! oracle (the real-GPU substitution), so the interesting number is the
//! DP partition time — which our Rust implementation reduces from the
//! paper's 327 s to well under a second.

use std::time::Instant;

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::model::find_model;
use cephalo::optimizer::{partition_state, DpOptimizer};
use cephalo::perfmodel::{CollectiveModel, Profiler, SyntheticOracle};
use cephalo::util::tablefmt::Table;

fn main() {
    let cluster = Cluster::cluster_b();
    let model = find_model("GPT 6.7B").unwrap();
    let batch = 512;

    let mut t = Table::new(
        "Table 7 — optimization runtime breakdown (GPT 6.7B, 64 GPUs, \
         batch 512)",
        &["subtask", "runtime (s)", "paper (s)"],
    );

    // Profile compute+memory: sample the oracle at m = 1..=8 per GPU.
    let oracle = SyntheticOracle::new(&cluster, &model, 42);
    let t0 = Instant::now();
    let profile = Profiler::default().profile(&cluster, &model, &oracle);
    let t_profile = t0.elapsed().as_secs_f64();
    t.add_row(vec!["profile compute+memory".into(),
                   format!("{t_profile:.3}"), "23 + 486".into()]);

    let t0 = Instant::now();
    let _coll = CollectiveModel::from_cluster(&cluster);
    let t_comm = t0.elapsed().as_secs_f64();
    t.add_row(vec!["profile communication".into(), format!("{t_comm:.3}"),
                   "150".into()]);

    let t0 = Instant::now();
    let (asg, stats) =
        DpOptimizer::default().solve(&profile, batch).expect("solve");
    let t_dp = t0.elapsed().as_secs_f64();
    t.add_row(vec!["partition compute (DP)".into(), format!("{t_dp:.3}"),
                   "327".into()]);

    let t0 = Instant::now();
    let mut per_gpu = asg.per_gpu.clone();
    partition_state(&profile, &mut per_gpu).expect("state partition");
    let t_state = t0.elapsed().as_secs_f64();
    t.add_row(vec!["partition state (greedy)".into(),
                   format!("{t_state:.3}"), "1".into()]);

    t.add_row(vec![
        "total".into(),
        format!("{:.3}", t_profile + t_comm + t_dp + t_state),
        "987".into(),
    ]);
    println!("{}", t.render());
    println!(
        "DP stats: {} states, {} transitions, granularity {} \
         (k_max {})",
        stats.states_visited, stats.transitions, stats.granularity,
        stats.k_max
    );
    // The paper's bound: the whole pipeline within 20 minutes. Ours must
    // be far below.
    assert!(t_dp < 60.0, "DP too slow: {t_dp}s");
    let w = Workload::prepare(Cluster::cluster_b(), "GPT 6.7B", 42).unwrap();
    assert_eq!(w.profile.num_gpus(), 64);
}
