//! Fig. 6 — Left: training TFLOPs as heterogeneous GPUs are added
//! (A10G-only -> +V100 -> all of Cluster B). Right: Cluster B vs a
//! homogeneous 32xA10G cluster with matched peak TFLOPs (984 vs 998).

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::sim::cephalo::tflops;
use cephalo::util::tablefmt::Table;

fn run(cluster: Cluster, model: &str, batch: usize) -> (f64, f64) {
    let w = Workload::prepare(cluster, model, 42).expect("profile");
    let (_, stats) = w.cephalo_throughput(batch).expect("plan");
    (tflops(&w.model, batch, stats.latency), stats.throughput)
}

fn main() {
    let model = "GPT 6.7B";
    let batch = 512;

    // Left: scaling across heterogeneous additions.
    let configs = [
        ("16xA10G (B subset)", Cluster::cluster_b_subset(&["A10G"])),
        ("+16xV100", Cluster::cluster_b_subset(&["A10G", "V100"])),
        ("all 64 (Cluster B)", Cluster::cluster_b()),
    ];
    let mut t = Table::new(
        &format!("Fig. 6 left — {model} @ {batch}: adding heterogeneous \
                  GPUs"),
        &["cluster", "peak TFLOPs", "training TFLOPs", "samples/s"],
    );
    let mut series = Vec::new();
    for (name, cluster) in configs {
        let peak = cluster.total_tflops();
        let (tf, tput) = run(cluster, model, batch);
        series.push(tf);
        t.add_row(vec![
            name.into(),
            format!("{peak:.0}"),
            format!("{tf:.1}"),
            format!("{tput:.2}"),
        ]);
    }
    println!("{}", t.render());
    assert!(series[1] > series[0] * 1.2, "V100s should add throughput");
    assert!(series[2] > series[1] * 1.2, "T4s should add throughput");
    assert!(
        series[2] > series[0] * 1.6,
        "paper: ~2x from A10G-only to all GPUs (got {:.2}x)",
        series[2] / series[0]
    );

    // Right: heterogeneous vs homogeneous at matched peak.
    let b = Cluster::cluster_b();
    let homo = Cluster::homogeneous("A10G", 32, 8, 100.0);
    let peak_b = b.total_tflops();
    let peak_h = homo.total_tflops();
    let (tf_b, _) = run(b, model, batch);
    let (tf_h, _) = run(homo, model, batch);
    let mut t2 = Table::new(
        &format!("Fig. 6 right — {model} @ {batch}: heterogeneous vs \
                  homogeneous"),
        &["cluster", "peak TFLOPs", "training TFLOPs", "ratio to homo"],
    );
    t2.add_row(vec!["Cluster B (64 mixed)".into(),
                    format!("{peak_b:.0}"), format!("{tf_b:.1}"),
                    format!("{:.2}", tf_b / tf_h)]);
    t2.add_row(vec!["32xA10G".into(), format!("{peak_h:.0}"),
                    format!("{tf_h:.1}"), "1.00".into()]);
    println!("{}", t2.render());
    assert!(
        tf_b > 0.7 * tf_h,
        "heterogeneous should be comparable to homogeneous: {:.2}",
        tf_b / tf_h
    );
    println!("shape check: near-2x heterogeneous scaling + comparable-to-\
              homogeneous  [ok]");
}
