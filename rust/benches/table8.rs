//! Table 8 (Supplementary D): FSDP / Whale / HAP / Cephalo on Cluster A
//! — the additional-baselines comparison. The paper's shape: Whale and
//! HAP train only BERT-Large; FSDP OOMs on the larger models and at
//! batch 256 for ViT-G / BERT-XLarge / Tiny Llama; Cephalo never OOMs.
//! All cells come from one parallel `plan::sweep` per workload.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{find_cell, outcome_cell, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::plan::{sweep, PlannerRegistry, SweepCell};
use cephalo::util::tablefmt::Table;

fn main() {
    let models = [
        "ViT-G", "ViT-e", "BERT-Large", "BERT-XLarge", "GPT 1.3B",
        "GPT 2.7B", "Tiny Llama", "Llama 3B",
    ];
    let systems = [
        SystemKind::Fsdp,
        SystemKind::Whale,
        SystemKind::Hap,
        SystemKind::Cephalo,
    ];
    let batches = [128usize, 256];
    let mut headers = vec!["System".to_string()];
    for m in models {
        headers.push(format!("{m} @128"));
        headers.push(format!("{m} @256"));
    }
    let mut t = Table::new(
        "Table 8 — additional baselines, Cluster A",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    let registry = PlannerRegistry::with_defaults();
    let planners: Vec<_> = systems
        .iter()
        .map(|s| registry.get(s.name()).expect("registered"))
        .collect();
    let workloads: Vec<Workload> = models
        .iter()
        .map(|m| {
            Workload::prepare(Cluster::cluster_a(), m, 42).expect("profile")
        })
        .collect();
    let grids: Vec<Vec<SweepCell>> = workloads
        .iter()
        .map(|w| sweep(&w.ctx(0), &planners, &batches, None))
        .collect();

    for system in systems {
        let mut row = vec![system.name().to_string()];
        for cells in &grids {
            for &batch in &batches {
                row.push(outcome_cell(
                    &find_cell(cells, system, batch).result,
                ));
            }
        }
        t.add_row(row);
    }
    println!("{}", t.render());

    // Shape checks, straight off the sweep grids.
    let ok = |cells: &[SweepCell], s: SystemKind, b: usize| {
        find_cell(cells, s, b).throughput()
    };
    let bert = &grids[2];
    assert!(ok(bert, SystemKind::Whale, 128).is_some());
    assert!(ok(bert, SystemKind::Hap, 128).is_some());
    let mut whale_ooms = 0;
    let mut hap_ooms = 0;
    for (i, cells) in grids.iter().enumerate() {
        if i == 2 {
            continue; // BERT-Large
        }
        if ok(cells, SystemKind::Whale, 128).is_none() {
            whale_ooms += 1;
        }
        if ok(cells, SystemKind::Hap, 128).is_none() {
            hap_ooms += 1;
        }
        // Cephalo never OOMs.
        assert!(ok(cells, SystemKind::Cephalo, 256).is_some());
    }
    assert!(whale_ooms >= 6, "Whale should OOM on most models");
    assert!(hap_ooms >= 6, "HAP should OOM on most models");
    // HAP's cross-node TP makes it slower than FSDP on BERT-Large.
    let hap = ok(bert, SystemKind::Hap, 128).unwrap();
    let fsdp = ok(bert, SystemKind::Fsdp, 128).unwrap();
    assert!(hap < fsdp, "HAP ({hap:.2}) should trail FSDP ({fsdp:.2})");
    // OOM cells render as "OOM" and name the failing configuration in
    // the underlying error (Table 4/5 presentation requirement).
    let whale_err = find_cell(&grids[0], SystemKind::Whale, 128);
    assert_eq!(outcome_cell(&whale_err.result), "OOM");
    let msg = whale_err.result.as_ref().unwrap_err().to_string();
    assert!(msg.contains("[Whale]"), "{msg}");
    println!("shape check: OOM pattern + HAP<FSDP hold  [ok]");
}
