//! Table 8 (Supplementary D): FSDP / Whale / HAP / Cephalo on Cluster A
//! — the additional-baselines comparison. The paper's shape: Whale and
//! HAP train only BERT-Large; FSDP OOMs on the larger models and at
//! batch 256 for ViT-G / BERT-XLarge / Tiny Llama; Cephalo never OOMs.

use cephalo::cluster::Cluster;
use cephalo::coordinator::report::{cell, throughput, SystemKind};
use cephalo::coordinator::Workload;
use cephalo::util::tablefmt::Table;

fn main() {
    let models = [
        "ViT-G", "ViT-e", "BERT-Large", "BERT-XLarge", "GPT 1.3B",
        "GPT 2.7B", "Tiny Llama", "Llama 3B",
    ];
    let systems = [
        SystemKind::Fsdp,
        SystemKind::Whale,
        SystemKind::Hap,
        SystemKind::Cephalo,
    ];
    let mut headers = vec!["System".to_string()];
    for m in models {
        headers.push(format!("{m} @128"));
        headers.push(format!("{m} @256"));
    }
    let mut t = Table::new(
        "Table 8 — additional baselines, Cluster A",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    let workloads: Vec<Workload> = models
        .iter()
        .map(|m| {
            Workload::prepare(Cluster::cluster_a(), m, 42).expect("profile")
        })
        .collect();
    for system in systems {
        let mut row = vec![system.name().to_string()];
        for w in &workloads {
            row.push(cell(w, 128, system));
            row.push(cell(w, 256, system));
        }
        t.add_row(row);
    }
    println!("{}", t.render());

    // Shape checks.
    let bert = &workloads[2];
    assert!(throughput(bert, 128, SystemKind::Whale).is_ok());
    assert!(throughput(bert, 128, SystemKind::Hap).is_ok());
    let mut whale_ooms = 0;
    let mut hap_ooms = 0;
    for (i, w) in workloads.iter().enumerate() {
        if i == 2 {
            continue; // BERT-Large
        }
        if throughput(w, 128, SystemKind::Whale).is_err() {
            whale_ooms += 1;
        }
        if throughput(w, 128, SystemKind::Hap).is_err() {
            hap_ooms += 1;
        }
        // Cephalo never OOMs.
        assert!(throughput(w, 256, SystemKind::Cephalo).is_ok());
    }
    assert!(whale_ooms >= 6, "Whale should OOM on most models");
    assert!(hap_ooms >= 6, "HAP should OOM on most models");
    // HAP's cross-node TP makes it slower than FSDP on BERT-Large.
    let hap = throughput(bert, 128, SystemKind::Hap).unwrap();
    let fsdp = throughput(bert, 128, SystemKind::Fsdp).unwrap();
    assert!(hap < fsdp, "HAP ({hap:.2}) should trail FSDP ({fsdp:.2})");
    println!("shape check: OOM pattern + HAP<FSDP hold  [ok]");
}
